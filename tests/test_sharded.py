"""Sharded parallel build + serve (graph/sharded.py, DESIGN.md §16).

What is being asserted:

  1. Streaming assignment: chunked-callable and array sources produce
     byte-identical spill plans; global ids partition [0, N); balanced
     routing respects the per-segment capacity; the one-shot-iterator
     misuse fails loudly; ``ops.nearest_centroid`` matches the argmin
     oracle (with banned-segment masking).
  2. The parity grid: a sharded build (inline and process-pool) is
     BIT-EXACT with a sequential ``SegmentedAnnIndex.build`` over the same
     assignment, across algo × backend — every exported segment array is
     equal, and fan-out searches agree after mapping global ids through
     each side's locator.
  3. Parallel query fan-out (``SegmentedAnnIndex.search`` /
     ``SegmentRouter``) returns results identical to the sequential loop.
  4. Lifecycle decoupling: the published manifest + per-segment snapshots
     load in a FRESH process (the attach-on-another-host step) and search
     identically; ``serve.init_from_manifest`` adopts the manifest as a
     durable recovery root.
  5. Graceful fallback: no mesh + no workers builds inline through the
     same code path; a 1-device mesh degrades the same way.
  6. The coordinator's assignment memory stays O(chunk + segments) — peak
     RSS growth while streaming a ~100 MB virtual dataset is a small
     fraction of materializing it (subprocess, getrusage).
  7. A sharded build emits one obs profile: a ``shard/build`` root span
     with one ``shard/segment`` child per segment carrying worker, phase
     split, and cost labels that sum to the workers' reported n_dists.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, serve
from repro.graph import AnnIndex, BuildParams
from repro.graph.segmented import SegmentedAnnIndex
from repro.graph.sharded import (
    ShardConfig,
    ShardedBuilder,
    ShardPlan,
    bootstrap_centroids,
    fanout_map,
    iter_chunks,
    model_parallel_wall,
    reservoir_sample,
    stream_assign,
)
from repro.kernels import ops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PARAMS = BuildParams(r_upper=8, r_base=16, ef=32, batch=32, max_layers=2)
N, D, S = 1200, 32, 3


def clustered(n: int = N, d: int = D, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 1.5
    x = centers[rng.integers(0, 8, n)]
    return (x + rng.normal(size=(n, d)).astype(np.float32) * 0.3).astype(
        np.float32
    )


@pytest.fixture(scope="module")
def data():
    return clustered()


@pytest.fixture(scope="module")
def queries():
    return clustered(24, D, seed=99)


def _config(tmpdir, **over) -> ShardConfig:
    kw = dict(
        n_segments=S, chunk_size=256, algo="hnsw", backend="fp32",
        params=PARAMS, sample_size=512, seed=0,
    )
    kw.update(over)
    return ShardConfig(**kw)


@pytest.fixture(scope="module")
def inline_result(data, tmp_path_factory):
    """One inline sharded build with a published manifest, shared below."""
    wd = tmp_path_factory.mktemp("inline")
    builder = ShardedBuilder(_config(wd), workdir=str(wd))
    return builder.build(data, snapshot_path=str(wd / "index"))


@pytest.fixture(scope="module")
def pool_result(data, tmp_path_factory):
    """One 2-worker process-pool build (spawn; disk is the transport)."""
    wd = tmp_path_factory.mktemp("pool")
    builder = ShardedBuilder(_config(wd), workers=2, workdir=str(wd))
    return builder.build(data, snapshot_path=str(wd / "index"))


def _map_local(seg_index, gids: np.ndarray) -> np.ndarray:
    """Global ids -> (segment, local) pairs via the index's locator
    (padding −1 stays −1), so id schemes with different global numbering
    compare on physical identity."""
    gids = np.asarray(gids)
    out = np.full(gids.shape + (2,), -1, np.int64)
    valid = gids >= 0
    out[valid] = seg_index._locate[gids[valid]]
    return out


# ---------------------------------------------------------------------------
# 1. Streaming assignment
# ---------------------------------------------------------------------------


class TestAssign:
    def test_chunked_callable_matches_array_source(self, data, tmp_path):
        cents = bootstrap_centroids(data, S, sample_size=512, seed=0)

        # balanced routing is a greedy pass over chunks, so equality holds
        # per chunk-partition: the callable must yield the same boundaries
        def chunks():
            for i in range(0, N, 256):
                yield data[i : i + 256]

        p1 = stream_assign(data, cents, str(tmp_path / "a"), chunk_size=256)
        p2 = stream_assign(
            chunks, cents, str(tmp_path / "b"), chunk_size=256, n_total=N
        )
        assert p1.seg_sizes == p2.seg_sizes
        for s in range(S):
            v1, g1 = p1.load_segment(s)
            v2, g2 = p2.load_segment(s)
            np.testing.assert_array_equal(g1, g2)
            np.testing.assert_array_equal(v1, v2)

    def test_gids_partition_and_locate(self, inline_result):
        plan = inline_result.plan
        allg = np.concatenate(plan.global_of())
        np.testing.assert_array_equal(np.sort(allg), np.arange(plan.n))
        loc = plan.locate()
        for s, gids in enumerate(plan.global_of()):
            assert (loc[gids, 0] == s).all()
            np.testing.assert_array_equal(loc[gids, 1], np.arange(len(gids)))

    def test_balanced_respects_capacity(self, data, tmp_path):
        cents = bootstrap_centroids(data, S, sample_size=512, seed=0)
        cap = -(-N // S)
        plan = stream_assign(data, cents, str(tmp_path / "c"), chunk_size=256)
        assert max(plan.seg_sizes) <= cap
        assert sum(plan.seg_sizes) == N

    def test_unbalanced_is_pure_nearest(self, data, tmp_path):
        cents = bootstrap_centroids(data, S, sample_size=512, seed=0)
        plan = stream_assign(
            data, cents, str(tmp_path / "u"), chunk_size=256, balanced=False
        )
        want = np.asarray(
            jnp.argmin(ops.l2_batch(jnp.asarray(data), jnp.asarray(cents)), axis=1)
        )
        loc = plan.locate()
        np.testing.assert_array_equal(loc[:, 0], want)

    def test_one_shot_iterator_rejected(self, data, tmp_path):
        builder = ShardedBuilder(_config(tmp_path), workdir=str(tmp_path))
        with pytest.raises(TypeError, match="re-creates"):
            builder.assign(iter([data]))

    def test_plan_round_trips(self, inline_result):
        plan = inline_result.plan
        again = ShardPlan.load(plan.spill_dir)
        assert again.seg_sizes == plan.seg_sizes
        assert (again.n, again.d) == (plan.n, plan.d)
        np.testing.assert_array_equal(again.centroids, plan.centroids)

    def test_reservoir_sample_shape_and_determinism(self, data):
        s1 = reservoir_sample(data, 300, seed=7)
        s2 = reservoir_sample(
            lambda: iter_chunks(data, 128), 300, seed=7
        )
        assert s1.shape == (300, D)
        np.testing.assert_array_equal(s1, s2)

    def test_nearest_centroid_matches_oracle(self, data):
        cents = jnp.asarray(data[:5])
        route, d2 = ops.nearest_centroid(jnp.asarray(data), cents)
        full = np.asarray(ops.l2_batch(jnp.asarray(data), cents))
        np.testing.assert_array_equal(np.asarray(route), full.argmin(axis=1))
        np.testing.assert_allclose(np.asarray(d2), full.min(axis=1), rtol=1e-6)
        banned = jnp.asarray(np.eye(5, dtype=bool)[0])
        route_b, _ = ops.nearest_centroid(jnp.asarray(data), cents, banned=banned)
        assert (np.asarray(route_b) != 0).all()


# ---------------------------------------------------------------------------
# 2. The parity grid: sharded ≡ sequential segmented, bit-exact
# ---------------------------------------------------------------------------


GRID = [
    ("hnsw", "fp32"),
    ("hnsw", "flash_blocked"),
    ("vamana", "fp32"),
    ("nsg", "flash_blocked"),
]


class TestParityGrid:
    @pytest.mark.parametrize("algo,backend", GRID)
    def test_sharded_equals_sequential_on_same_assignment(
        self, data, queries, tmp_path, algo, backend
    ):
        bk = (
            dict(d_f=16, m_f=8, kmeans_iters=5)
            if backend.startswith("flash") else None
        )
        cfg = _config(
            tmp_path, algo=algo, backend=backend, n_segments=2,
            backend_kwargs=bk,
        )
        builder = ShardedBuilder(cfg, workdir=str(tmp_path))
        res = builder.build(data[:800])
        assert res.mode == "inline"
        plan = res.plan
        seq = SegmentedAnnIndex.build(
            (plan.load_segment(s)[0] for s in range(2)),
            algo=algo, backend=backend, params=PARAMS, seed=0,
            backend_kwargs=bk,
        )
        # bit-exact per-segment state: every exported array equal
        for s in range(2):
            _, a = res.index.segments[s].export_state()
            _, b = seq.segments[s].export_state()
            assert set(a) == set(b)
            for name in a:
                np.testing.assert_array_equal(
                    a[name], b[name], err_msg=f"{algo}/{backend} seg{s} {name}"
                )
        # fan-out search parity on physical (segment, local) identity —
        # global numbering differs (stream order vs contiguous ranges)
        r1 = res.index.search(queries, k=5)
        r2 = seq.search(queries, k=5)
        np.testing.assert_array_equal(
            np.asarray(r1.dists), np.asarray(r2.dists)
        )
        np.testing.assert_array_equal(
            _map_local(res.index, np.asarray(r1.ids)),
            _map_local(seq, np.asarray(r2.ids)),
        )

    def test_pool_build_is_bit_exact_with_inline(
        self, inline_result, pool_result
    ):
        """Same assignment + same per-segment program in another process
        must produce the same bits (jax CPU determinism) — the claim that
        lets a fleet build segments anywhere."""
        assert pool_result.mode == "pool"
        assert all(m["pid"] != os.getpid() for m in pool_result.segments)
        for s in range(S):
            _, a = inline_result.index.segments[s].export_state()
            _, b = pool_result.index.segments[s].export_state()
            for name in a:
                np.testing.assert_array_equal(a[name], b[name])

    def test_worker_metrics_reported(self, pool_result):
        for m in pool_result.segments:
            assert m["n_vectors"] > 0
            assert m["wall_s"] > 0
            assert m["n_dists"] > 0
            assert m["max_rss_mb"] > 0
            assert m["phases"] is not None and sum(m["phases"].values()) > 0
            # the worker wrote into the staging dir; after the atomic
            # publish the segment lives under the final snapshot path
            assert os.path.isdir(
                serve.segment_dir(pool_result.snapshot_path, m["seg"])
            )


# ---------------------------------------------------------------------------
# 3. Parallel fan-out search ≡ sequential loop
# ---------------------------------------------------------------------------


class TestFanout:
    def test_segmented_search_fanout_parity(self, inline_result, queries):
        idx = inline_result.index
        par = idx.search(queries, k=5)
        seq = idx.search(queries, k=5, fanout=False)
        np.testing.assert_array_equal(np.asarray(par.ids), np.asarray(seq.ids))
        np.testing.assert_array_equal(
            np.asarray(par.dists), np.asarray(seq.dists)
        )
        assert float(par.n_scan) == float(seq.n_scan)

    def test_router_fanout_parity(self, inline_result, queries):
        idx = inline_result.index
        router = serve.SegmentRouter(
            idx, n_probe=S, k=5, ef=32, q_buckets=(8, 32)
        ).warmup()
        par = router.search(queries)
        router.fanout = False
        seq = router.search(queries)
        np.testing.assert_array_equal(np.asarray(par.ids), np.asarray(seq.ids))
        np.testing.assert_array_equal(
            np.asarray(par.dists), np.asarray(seq.dists)
        )
        assert router.stats()["fanout"] is False

    def test_fanout_map_order_and_fallback(self):
        items = list(range(17))
        assert fanout_map(lambda x: x * x, items) == [x * x for x in items]
        assert fanout_map(lambda x: -x, items, parallel=False) == [
            -x for x in items
        ]

    def test_model_parallel_wall(self):
        assert model_parallel_wall([3, 3, 3, 3], 1) == pytest.approx(12.0)
        assert model_parallel_wall([3, 3, 3, 3], 4) == pytest.approx(3.0)
        assert model_parallel_wall([4, 3, 2, 1], 2) == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# 4. Manifest lifecycle: fresh-process attach + durable adoption
# ---------------------------------------------------------------------------


class TestManifest:
    def test_manifest_loads_and_matches(self, pool_result, queries):
        loaded = serve.load_index(pool_result.snapshot_path)
        r1 = pool_result.index.search(queries, k=5)
        r2 = loaded.search(queries, k=5)
        np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))

    def test_attach_in_fresh_process(self, pool_result, queries, tmp_path):
        """The other-host story end-to-end: a process that took no part in
        the build loads the published manifest, serves it, and adopts it
        as a durable recovery root."""
        want = np.asarray(pool_result.index.search(queries, k=5).ids)
        np.save(tmp_path / "queries.npy", queries)
        np.save(tmp_path / "want.npy", want)
        script = textwrap.dedent(f"""
            import numpy as np
            from repro import serve
            q = np.load({str(tmp_path / 'queries.npy')!r})
            want = np.load({str(tmp_path / 'want.npy')!r})
            idx = serve.load_index({pool_result.snapshot_path!r})
            got = np.asarray(idx.search(q, k=5).ids)
            assert np.array_equal(got, want), "fresh-process search diverged"
            root, live = serve.init_from_manifest(
                {str(tmp_path / 'root')!r}, {pool_result.snapshot_path!r}
            )
            rec = serve.recover(root)
            got2 = np.asarray(rec.index.search(q, k=5).ids)
            assert np.array_equal(got2, want)
            assert rec.replayed == 0 and not rec.degraded
            print("FRESH-ATTACH-OK")
        """)
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        proc = subprocess.run(
            [sys.executable, "-c", script], env=env, capture_output=True,
            text=True, timeout=600,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "FRESH-ATTACH-OK" in proc.stdout

    def test_quarantine_on_segment_corruption(self, pool_result, tmp_path):
        import shutil

        root = str(tmp_path / "corrupt")
        shutil.copytree(pool_result.snapshot_path, root)
        with open(os.path.join(serve.segment_dir(root, 1), "arrays.npz"), "r+b") as f:
            f.seek(60)
            b = f.read(1)
            f.seek(60)
            f.write(bytes([b[0] ^ 0xFF]))
        idx = serve.load_index(root, quarantine=True)
        assert idx.quarantined == {1}
        assert idx.health()["degraded"]


# ---------------------------------------------------------------------------
# 5. Graceful single-device fallback + facade entry point
# ---------------------------------------------------------------------------


class TestFallback:
    def test_no_mesh_no_workers_runs_inline(self, inline_result):
        assert inline_result.mode == "inline"
        assert inline_result.n_workers == 1

    def test_one_device_mesh_degrades_to_inline(self, data, tmp_path):
        from repro.launch.mesh import make_segment_mesh

        mesh = make_segment_mesh(1)
        builder = ShardedBuilder(
            _config(tmp_path, n_segments=2), mesh=mesh, workdir=str(tmp_path)
        )
        res = builder.build(data[:400])
        assert res.mode == "inline"
        assert res.index.n == 400

    def test_build_streaming_facade(self, data, queries, tmp_path):
        idx = SegmentedAnnIndex.build_streaming(
            data, n_segments=S, chunk_size=256, algo="hnsw", backend="fp32",
            params=PARAMS, seed=0, workdir=str(tmp_path / "a"),
        )
        ref = ShardedBuilder(
            ShardConfig(n_segments=S, chunk_size=256, algo="hnsw",
                        backend="fp32", params=PARAMS, seed=0),
            workdir=str(tmp_path / "b"),
        ).build(data)
        r1 = idx.search(queries, k=5)
        r2 = ref.index.search(queries, k=5)
        np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))

    def test_segmented_build_accepts_generator(self, data, queries):
        segs = [data[i * 400 : (i + 1) * 400] for i in range(3)]
        from_gen = SegmentedAnnIndex.build(
            (s for s in segs), algo="hnsw", backend="fp32", params=PARAMS
        )
        from_list = SegmentedAnnIndex.build(
            segs, algo="hnsw", backend="fp32", params=PARAMS
        )
        r1 = from_gen.search(queries, k=5)
        r2 = from_list.search(queries, k=5)
        np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))


# ---------------------------------------------------------------------------
# 6. Coordinator memory: assignment is O(chunk + segments)
# ---------------------------------------------------------------------------


MEMORY_SCRIPT = """
import resource, numpy as np
from repro.graph.sharded import bootstrap_centroids, stream_assign

N, D, CHUNK = 262144, 96, 16384          # ~96 MB of f32 if materialized

def chunks():
    for i in range(N // CHUNK):
        rng = np.random.default_rng(i)   # regenerable: nothing retained
        yield rng.normal(size=(CHUNK, D)).astype(np.float32)

cents = bootstrap_centroids(chunks, 8, sample_size=4096, seed=0,
                            chunk_size=CHUNK)
base = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
plan = stream_assign(chunks, cents, "@SPILL@", chunk_size=CHUNK, n_total=N)
peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
assert sum(plan.seg_sizes) == N
grown = peak - base
full_mb = N * D * 4 / 1e6
assert grown < 0.5 * full_mb, (
    f"assignment grew RSS by {grown:.0f} MB streaming a {full_mb:.0f} MB "
    "dataset - not O(chunk + segments)")
print(f"MEM-OK grew {grown:.1f} MB for {full_mb:.0f} MB dataset")
"""


class TestMemory:
    def test_streaming_assignment_memory_bound(self, tmp_path):
        script = MEMORY_SCRIPT.replace("@SPILL@", str(tmp_path / "spill"))
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        proc = subprocess.run(
            [sys.executable, "-c", script], env=env, capture_output=True,
            text=True, timeout=600,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "MEM-OK" in proc.stdout


# ---------------------------------------------------------------------------
# 7. Mesh mode (multi-device shard_map) in a subprocess
# ---------------------------------------------------------------------------


MESH_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax, jax.numpy as jnp
from repro.graph import BuildParams
from repro.graph.sharded import ShardConfig, ShardedBuilder
from repro.graph.segmented import build_segments_vmapped, fit_shared_coder
from repro.graph.engine import sample_levels, prefix_entries
from repro.launch.mesh import make_segment_mesh

assert len(jax.devices()) == 2
rng = np.random.default_rng(0)
data = rng.normal(size=(600, 32)).astype(np.float32)
P = BuildParams(r_upper=8, r_base=16, ef=32, batch=32, max_layers=2)
cfg = ShardConfig(n_segments=2, chunk_size=256, params=P, sample_size=512,
                  seed=0, backend_kwargs=dict(d_f=16, m_f=8, kmeans_iters=5))
res = ShardedBuilder(cfg, mesh=make_segment_mesh()).build(data)
assert res.mode == "mesh", res.mode
r = res.index.search(rng.normal(size=(4, 32)).astype(np.float32), k=5)
assert (np.asarray(r.ids) >= 0).all()
plan = res.plan
n_s = plan.seg_sizes[0]
stacked = np.stack([plan.load_segment(s)[0] for s in range(2)])
coder = fit_shared_coder(jax.random.PRNGKey(0),
                         jnp.asarray(stacked.reshape(-1, 32)[:512]),
                         d_f=16, m_f=8, kmeans_iters=5)
levels = np.stack([sample_levels(s, n_s, r_upper=8, max_layers=2)
                   for s in range(2)])
entries = np.stack([prefix_entries(levels[s], 32) for s in range(2)])
ref = build_segments_vmapped(jnp.asarray(stacked), coder, jnp.asarray(levels),
                             jnp.asarray(entries), params=P)
for s in range(2):
    got = np.asarray(res.index.segments[s].graph.adj0)
    want = np.asarray(ref.index.adj0[s])
    assert np.array_equal(got, want), f"seg {s}: shard_map != vmapped"
print("MESH-OK")
"""


class TestMesh:
    def test_mesh_build_matches_vmapped_reference(self):
        """shard_map over forced host devices ≡ the vmapped single-device
        reference program — the mesh deployment changes placement, never
        results."""
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        proc = subprocess.run(
            [sys.executable, "-c", MESH_SCRIPT], env=env,
            capture_output=True, text=True, timeout=600,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "MESH-OK" in proc.stdout


# ---------------------------------------------------------------------------
# 8. Observability: one profile per sharded build
# ---------------------------------------------------------------------------


class TestObsProfile:
    def test_build_emits_span_tree_and_counters(self, data, tmp_path):
        before = obs.snapshot().get("counters", {})
        obs.enable()
        obs.clear_spans()
        try:
            cfg = _config(tmp_path, n_segments=2, sample_size=256)
            res = ShardedBuilder(cfg, workdir=str(tmp_path)).build(data[:400])
        finally:
            obs.disable()
        roots = obs.spans("shard/build")
        assert len(roots) == 1
        root = roots[0]
        assert root.attrs["segments"] == 2
        segs = [c for c in root.children if c.name == "shard/segment"]
        assert len(segs) == 2
        total = sum(m["n_dists"] for m in res.segments)
        assert total > 0
        assert root.n_dists == pytest.approx(total)
        for sp, m in zip(segs, res.segments):
            assert sp.attrs["segment"] == m["seg"]
            assert sp.attrs["worker"] == m["pid"]
            assert sp.attrs["n"] == m["n_vectors"]
            assert sp.n_dists == pytest.approx(m["n_dists"])
            assert sp.attrs["phases"] == m["phases"]
        assert len(obs.spans("shard/assign")) == 1
        after = obs.snapshot().get("counters", {})

        def delta(name):
            return sum(
                v for k, v in after.items() if k.startswith(name)
            ) - sum(v for k, v in before.items() if k.startswith(name))

        assert delta("shard_segments_built_total") == 2
        assert delta("shard_segment_vectors_total") == 400
        # the dists counter ticks once per (segment, phase) bucket
        ptotal = sum(
            sum(m["phases"].values()) for m in res.segments if m["phases"]
        )
        assert ptotal > 0
        assert delta("shard_build_dists_total") == pytest.approx(ptotal)
