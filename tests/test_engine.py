"""Build-engine tests (graph/engine.py + multi-expansion beam).

Three contracts:
  1. ``beam_search(width=1)`` is bit-exact with the seed's single-expansion
     beam (a verbatim reference copy below) on the fp32 and flash backends —
     ids, dists, and both cost counters.
  2. HNSW / Vamana / NSG built through the engine hit the same recall floors
     the seed suite asserted, and width > 1 preserves them.
  3. Hygiene: no module imports underscore-private helpers across module
     boundaries (the refactor's whole point).
"""

from __future__ import annotations

import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import graph
from repro.graph.beam import INF, beam_search, greedy_descent
from repro.graph.engine import BuildEngine, BuildParams, CostAccount
from repro.graph.hnsw import HNSWParams, build_hnsw, search_hnsw
from repro.graph.knn import exact_knn, recall_at_k
from repro.graph.nsg import build_nsg
from repro.graph.vamana import build_vamana, search_flat_result

PARAMS = HNSWParams(r_upper=8, r_base=16, ef=32, batch=16, max_layers=3)


# ---------------------------------------------------------------------------
# Reference: the seed's single-expansion beam, kept verbatim as the oracle
# ---------------------------------------------------------------------------


def _merge_ref(ids_a, d_a, exp_a, ids_b, d_b, exp_b, ef):
    ids = jnp.concatenate([ids_a, ids_b])
    d = jnp.concatenate([d_a, d_b])
    exp = jnp.concatenate([exp_a, exp_b])
    _, idx = jax.lax.top_k(-d, ef)
    return ids[idx], d[idx], exp[idx]


def _seed_neighbor_dists(backend, qctx, node, ids):
    """The seed backends' per-node neighbor_dists dispatch (removed from the
    protocol when the batch form replaced it): blocked-mirror row read when
    the width matches, gather fallback otherwise."""
    nbr_codes = getattr(backend, "nbr_codes", None)
    if nbr_codes is not None and ids.shape[-1] == nbr_codes.shape[1]:
        from repro.core import flash as flash_mod

        rows = nbr_codes[node]  # (R, M) int32 | (R, ceil(M/2)) packed uint8
        if nbr_codes.dtype == jnp.uint8:
            rows = flash_mod.unpack_codes(rows, backend.coder.m_f)
        return flash_mod.adc_lookup(qctx.adt_q, rows).astype(jnp.float32)
    return backend.query_dists(qctx, ids)


def seed_beam_search(backend, qctx, adjacency, entry_ids, *, ef, max_iters=None):
    """The pre-refactor beam_search (one vertex per while_loop iteration)."""
    n, r = adjacency.shape
    e = entry_ids.shape[0]
    max_iters = max_iters if max_iters is not None else 4 * ef + 8

    valid_e = entry_ids >= 0
    safe_e = jnp.where(valid_e, entry_ids, 0)
    d_e = jnp.where(valid_e, backend.query_dists(qctx, safe_e), INF)
    visited = jnp.zeros((n,), bool)
    visited = visited.at[safe_e].max(valid_e)

    pad = ef - e
    beam_ids = jnp.concatenate([entry_ids, jnp.full((pad,), -1, jnp.int32)])
    beam_d = jnp.concatenate([d_e, jnp.full((pad,), INF)])
    beam_exp = jnp.concatenate([~valid_e, jnp.ones((pad,), bool)])
    order = jnp.argsort(beam_d)
    beam_ids, beam_d, beam_exp = beam_ids[order], beam_d[order], beam_exp[order]

    def cond(state):
        beam_ids, beam_d, beam_exp, visited, it, nd = state
        best_unexp = jnp.min(jnp.where(beam_exp, INF, beam_d))
        worst = beam_d[ef - 1]
        return (best_unexp <= worst) & (best_unexp < INF) & (it < max_iters)

    def body(state):
        beam_ids, beam_d, beam_exp, visited, it, nd = state
        bi = jnp.argmin(jnp.where(beam_exp, INF, beam_d))
        node = beam_ids[bi]
        beam_exp = beam_exp.at[bi].set(True)
        nbrs = adjacency[jnp.maximum(node, 0)]
        ok = (nbrs >= 0) & (node >= 0)
        safe = jnp.where(ok, nbrs, 0)
        ok &= ~visited[safe]
        d_new = jnp.where(
            ok, _seed_neighbor_dists(backend, qctx, node, safe), INF
        )
        visited = visited.at[safe].max(ok)
        ids_new = jnp.where(ok, safe, -1)
        beam_ids, beam_d, beam_exp = _merge_ref(
            beam_ids, beam_d, beam_exp, ids_new, d_new,
            jnp.ones((r,), bool) & ~ok, ef,
        )
        return beam_ids, beam_d, beam_exp, visited, it + 1, nd + jnp.sum(ok)

    state = (beam_ids, beam_d, beam_exp, visited, jnp.int32(0), jnp.sum(valid_e))
    beam_ids, beam_d, beam_exp, visited, it, nd = jax.lax.while_loop(
        cond, body, state
    )
    return beam_ids, beam_d, it, nd


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def truth(small_data):
    data, queries = small_data
    ids, d = exact_knn(queries, data, k=10)
    return ids, d


@pytest.fixture(scope="module")
def fp32_graph(small_data):
    """A built base-layer adjacency to beam-search over (fp32 backend)."""
    data, _ = small_data
    be = graph.make_backend("fp32", data)
    index, _ = build_hnsw(data, be, params=PARAMS)
    return be, index


@pytest.fixture(scope="module")
def flash_graph(small_data, key):
    data, _ = small_data
    be = graph.make_backend(
        "flash", data, key, d_f=32, m_f=16, l_f=4, h=8, kmeans_iters=10
    )
    index, _ = build_hnsw(data, be, params=PARAMS)
    return be, index


# ---------------------------------------------------------------------------
# 1) width=1 exactness against the seed beam
# ---------------------------------------------------------------------------


class TestWidthOneExact:
    def _assert_match(self, be, adj, queries, *, ef):
        for qi in range(queries.shape[0]):
            qctx = be.prepare_query(queries[qi])
            ref_ids, ref_d, ref_hops, ref_nd = seed_beam_search(
                be, qctx, adj, jnp.asarray([0]), ef=ef
            )
            res = beam_search(be, qctx, adj, jnp.asarray([0]), ef=ef, width=1)
            np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref_ids))
            np.testing.assert_array_equal(
                np.asarray(res.dists), np.asarray(ref_d)
            )
            assert int(res.n_dists) == int(ref_nd)
            assert int(res.n_hops) == int(ref_hops)

    def test_fp32_exact(self, small_data, fp32_graph):
        _, queries = small_data
        be, index = fp32_graph
        self._assert_match(be, index.adj0, queries[:16], ef=32)

    def test_flash_exact(self, small_data, flash_graph):
        _, queries = small_data
        be, index = flash_graph
        self._assert_match(be, index.adj0, queries[:16], ef=32)

    def test_flash_blocked_exact(self, small_data, key):
        """Blocked mirror path (kernel-routed batch scoring) stays bit-exact."""
        data, queries = small_data
        be = graph.make_backend(
            "flash_blocked", data, key, d_f=32, m_f=16, kmeans_iters=10,
            r_for_blocked=PARAMS.r_base,
        )
        index, _ = build_hnsw(data, be, params=PARAMS)
        self._assert_match(index.backend, index.adj0, queries[:8], ef=32)

    def test_width_caps_at_ef(self, small_data, fp32_graph):
        """width > ef is clamped, not an error."""
        data, queries = small_data
        be, index = fp32_graph
        qctx = be.prepare_query(queries[0])
        res = beam_search(be, qctx, index.adj0, jnp.asarray([0]), ef=4, width=64)
        assert int(jnp.sum(res.ids >= 0)) > 0


class TestWidthQuality:
    @pytest.mark.parametrize("width", [2, 4, 8])
    def test_wider_beam_same_targets(self, small_data, fp32_graph, truth, width):
        """Multi-expansion search keeps recall (it visits a superset-ish
        frontier; termination is unchanged)."""
        data, queries = small_data
        be, index = fp32_graph
        res1 = search_hnsw(index, queries, k=10, ef_search=64)
        resw = search_hnsw(index, queries, k=10, ef_search=64, width=width)
        r1 = recall_at_k(res1.ids, truth[0], 10)
        rw = recall_at_k(resw.ids, truth[0], 10)
        assert rw >= r1 - 0.02

    @pytest.mark.parametrize("width", [2, 4])
    def test_wider_beam_fewer_hops_more_density(self, small_data, fp32_graph, width):
        """W>1 runs fewer iterations; each evaluates a denser block."""
        data, queries = small_data
        be, index = fp32_graph
        qctx = be.prepare_query(queries[0])
        r1 = beam_search(be, qctx, index.adj0, jnp.asarray([0]), ef=32, width=1)
        rw = beam_search(
            be, qctx, index.adj0, jnp.asarray([0]), ef=32, width=width
        )
        # the W-wide frontier covers at least the classic frontier (small
        # slack: dedup/termination details shift a few evaluations)
        assert int(rw.n_hops) >= int(r1.n_hops) // width
        assert int(rw.n_dists) >= int(0.9 * int(r1.n_dists))


# ---------------------------------------------------------------------------
# 2) engine-built indexes hit the seed recall floors
# ---------------------------------------------------------------------------


class TestEngineRecallFloors:
    def test_hnsw_fp32_floor(self, small_data, fp32_graph, truth):
        data, queries = small_data
        _, index = fp32_graph
        res = search_hnsw(index, queries, k=10, ef_search=64)
        assert recall_at_k(res.ids, truth[0], 10) >= 0.9

    def test_hnsw_fp32_widened_build_floor(self, small_data, truth):
        data, queries = small_data
        be = graph.make_backend("fp32", data)
        import dataclasses

        index, _ = build_hnsw(
            data, be, params=dataclasses.replace(PARAMS, width=4)
        )
        res = search_hnsw(index, queries, k=10, ef_search=64)
        assert recall_at_k(res.ids, truth[0], 10) >= 0.9

    def test_hnsw_flash_floor(self, small_data, flash_graph, truth):
        data, queries = small_data
        _, index = flash_graph
        res = search_hnsw(
            index, queries, k=10, ef_search=128, rerank_vectors=data
        )
        assert recall_at_k(res.ids, truth[0], 10) >= 0.85

    def test_vamana_floor(self, small_data, truth):
        data, queries = small_data
        be = graph.make_backend("fp32", data)
        idx, _ = build_vamana(
            data, be,
            params=HNSWParams(r_upper=8, r_base=24, ef=96, batch=16, alpha=1.2),
        )
        res = search_flat_result(idx, queries, k=10, ef_search=96)
        assert recall_at_k(res.ids, truth[0], 10) >= 0.9

    def test_nsg_floor(self, small_data, key, truth):
        data, queries = small_data
        be = graph.make_backend(
            "flash", data, key, d_f=32, m_f=16, kmeans_iters=10
        )
        idx, _knn = build_nsg(
            data, be, params=HNSWParams(r_base=24, ef=96, batch=16), knn_k=24
        )
        res = search_flat_result(
            idx, queries, k=10, ef_search=128, rerank_vectors=data
        )
        assert recall_at_k(res.ids, truth[0], 10) >= 0.8


# ---------------------------------------------------------------------------
# 3) engine API pieces + cost accounting + hygiene
# ---------------------------------------------------------------------------


class TestEngineAPI:
    def test_acquire_select_shapes(self, small_data, fp32_graph):
        data, _ = small_data
        be, index = fp32_graph
        engine = BuildEngine(PARAMS)
        qctx = jax.vmap(be.prepare_query)(data[:4])
        entries = jnp.zeros((4,), jnp.int32)
        res = engine.acquire(be, qctx, index.adj0, entries)
        assert res.ids.shape == (4, PARAMS.ef)
        sel = engine.select(be, res.ids, res.dists, r=PARAMS.r_base)
        assert sel.ids.shape == (4, PARAMS.r_base)

    def test_closest_selection_policy(self, small_data, fp32_graph):
        data, _ = small_data
        be, index = fp32_graph
        engine = BuildEngine(
            BuildParams(r_base=16, ef=32, select_mode="closest")
        )
        qctx = be.prepare_query(data[0])
        res = beam_search(be, qctx, index.adj0, jnp.asarray([0]), ef=32)
        sel = engine.select_one(be, res.ids, res.dists, r=8)
        # plain top-8: exactly the beam's first 8 valid entries
        np.testing.assert_array_equal(
            np.asarray(sel.ids), np.asarray(res.ids[:8])
        )

    def test_cost_account_zero_and_add(self):
        acct = CostAccount.zero()
        assert float(acct.n_dists) == 0.0 and float(acct.n_hops) == 0.0

    def test_search_counts_descent_dists(self, small_data, fp32_graph):
        """Upper-layer descent evaluations are no longer dropped."""
        data, queries = small_data
        be, index = fp32_graph
        full = search_hnsw(index, queries, k=10, ef_search=64)
        base_only = search_hnsw(index, queries, k=10, ef_search=64, max_layers=1)
        assert float(full.n_dists) > float(base_only.n_dists)

    def test_greedy_descent_counts(self, small_data, fp32_graph):
        data, _ = small_data
        be, index = fp32_graph
        qctx = be.prepare_query(data[0])
        res = greedy_descent(be, qctx, index.adj0, jnp.int32(0))
        assert int(res.n_dists) >= 1

    def test_derived_max_layers_matches_explicit(
        self, small_data, fp32_graph, truth
    ):
        data, queries = small_data
        _, index = fp32_graph
        derived = search_hnsw(index, queries, k=10, ef_search=64)
        explicit = search_hnsw(index, queries, k=10, ef_search=64, max_layers=3)
        np.testing.assert_array_equal(
            np.asarray(derived.ids), np.asarray(explicit.ids)
        )


# ---------------------------------------------------------------------------
# 4) bulk construction strategy (DESIGN.md §12)
# ---------------------------------------------------------------------------


BULK_PARAMS = HNSWParams(r_upper=6, r_base=12, ef=24, batch=16, max_layers=2)
BULK_FLASH_KW = dict(d_f=32, m_f=16, l_f=4, h=8, kmeans_iters=4)


def _base_reach(ann) -> float:
    """Fraction of base-layer vertices reachable from the entry point."""
    from repro.graph.engine import bfs_reachable

    g = ann.graph
    adj = np.asarray(g.adj0 if ann.layered else g.adj)
    return float(bfs_reachable(adj, int(g.entry)).mean())


class TestBulkStrategyParity:
    """strategy="bulk" builds the same kind of graph the incremental loop
    does: fully reachable, same recall neighborhood, same maintenance
    behavior — only candidate acquisition differs (DESIGN.md §12)."""

    N = 320

    @pytest.mark.parametrize(
        "algo,backend,kw",
        [
            ("hnsw", "fp32", {}),
            ("hnsw", "flash_blocked", BULK_FLASH_KW),
            ("vamana", "fp32", {}),
            ("nsg", "flash", BULK_FLASH_KW),
        ],
    )
    def test_parity_grid(self, small_data, algo, backend, kw):
        from repro.index import AnnIndex

        data, queries = small_data
        sub, qs = data[: self.N], queries[:32]
        tids, _ = exact_knn(qs, sub, k=10)
        recs = {}
        for strat in ("incremental", "bulk"):
            ann = AnnIndex.build(
                sub, algo=algo, backend=backend, params=BULK_PARAMS,
                backend_kwargs=dict(kw) or None, strategy=strat,
            )
            assert ann.build_strategy == strat
            # bulk runs an explicit reachability repair and must be fully
            # connected; the incremental loop has no such pass (reverse-
            # edge eviction can orphan an early vertex) so it only gets
            # the same near-full bar its own builders have always met.
            reach = _base_reach(ann)
            if strat == "bulk":
                assert reach == 1.0, f"{algo}/{backend}/{strat}"
            else:
                assert reach >= 0.99, f"{algo}/{backend}/{strat}"
            res = ann.search(qs, k=10, ef=96)
            recs[strat] = float(recall_at_k(res.ids, tids, 10))
        # recall parity at small n: the bulk graph must not trail the
        # incremental one by more than minor selection noise
        assert recs["bulk"] >= recs["incremental"] - 0.05, recs

    def test_bulk_snapshot_roundtrip_bit_exact(self, small_data):
        from repro.index import AnnIndex

        data, queries = small_data
        ann = AnnIndex.build(
            data[: self.N], algo="hnsw", backend="flash_blocked",
            params=BULK_PARAMS, backend_kwargs=BULK_FLASH_KW, strategy="bulk",
        )
        meta, arrays = ann.export_state()
        assert meta["strategy"] == "bulk"
        back = AnnIndex.restore(meta, arrays)
        assert back.build_strategy == "bulk"
        np.testing.assert_array_equal(
            np.asarray(back.graph.adj0), np.asarray(ann.graph.adj0)
        )
        np.testing.assert_array_equal(
            np.asarray(back.graph.adj_up), np.asarray(ann.graph.adj_up)
        )
        a = ann.search(queries[:16], k=10, ef=64)
        b = back.search(queries[:16], k=10, ef=64)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(
            np.asarray(a.dists), np.asarray(b.dists)
        )

    def test_add_after_bulk_matches_add_after_incremental(self, small_data):
        """add() is the dynamic path regardless of how the base was built:
        same appended ids, same insert_batch routing, and the new vectors
        are immediately findable on both bases."""
        from repro.index import AnnIndex

        data, _ = small_data
        base, extra = data[: self.N], data[self.N : self.N + 48]
        for strat in ("bulk", "incremental"):
            ann = AnnIndex.build(
                base, algo="hnsw", backend="flash_blocked",
                params=BULK_PARAMS, backend_kwargs=BULK_FLASH_KW,
                strategy=strat,
            )
            stats = ann.add(extra)
            assert ann.n == self.N + 48
            assert float(stats.n_dists) > 0
            # growth never reruns the bulk bootstrap: the recorded build
            # strategy is untouched and ids append in input order
            assert ann.build_strategy == strat
            res = ann.search(extra, k=1, ef=64)
            hit = np.asarray(res.ids)[:, 0] == np.arange(
                self.N, self.N + 48
            )
            assert hit.mean() >= 0.9, f"after {strat}: {hit.mean():.2f}"


class TestStructuralRepairTiling:
    """Mostly-island graphs drive ``repair_reachability`` into its structural
    graft path, whose (unreachable x everyone) distance rows are computed in
    fixed row tiles (a monolithic call materializes an O(n² · d) backend
    workspace). The tiling must be invisible: any tile budget produces the
    same rows, hence the same grafts, hence the same graph."""

    BLOBS, PER, D = 4, 96, 16

    def _islands(self):
        """4 far-apart blobs wired as per-blob directed rings: from entry 0
        only blob 0 is reachable — 3n/4 unreachable, which is past the
        ``n // 4`` cutoff where repair skips re-insertion and goes straight
        to the structural pair_dists rows."""
        rng = np.random.default_rng(7)
        centers = rng.normal(size=(self.BLOBS, self.D)).astype(np.float32)
        data = np.concatenate([
            50.0 * c + rng.normal(size=(self.PER, self.D)).astype(np.float32)
            for c in centers
        ])
        n = data.shape[0]
        r = BULK_PARAMS.r_base
        adj0 = np.full((n, r), -1, np.int32)
        adj0_d = np.full((n, r), np.inf, np.float32)
        for b in range(self.BLOBS):
            lo = b * self.PER
            for i in range(self.PER):
                j = lo + (i + 1) % self.PER
                adj0[lo + i, 0] = j
                adj0_d[lo + i, 0] = float(
                    ((data[lo + i] - data[j]) ** 2).sum()
                )
        return data, adj0, adj0_d

    def _repair(self, data, adj0, adj0_d):
        from repro.graph.backends import FP32Backend
        from repro.graph.engine import repair_reachability

        n = data.shape[0]
        levels = np.zeros(n, np.int32)
        adj_up = np.full((n, BULK_PARAMS.r_upper), -1, np.int32)
        adj_up_d = np.full((n, BULK_PARAMS.r_upper), np.inf, np.float32)
        return repair_reachability(
            jnp.asarray(data), jnp.asarray(adj0), jnp.asarray(adj0_d),
            jnp.asarray(adj_up), jnp.asarray(adj_up_d),
            FP32Backend(jnp.asarray(data)), levels, 0, params=BULK_PARAMS,
        )

    def test_tile_budget_invariant_and_fully_connected(self, monkeypatch):
        from repro.graph.engine import bfs_reachable

        data, adj0, adj0_d = self._islands()
        n = data.shape[0]
        ref_adj, ref_d, _, _, _, ref_nd, _ = self._repair(data, adj0, adj0_d)
        assert bfs_reachable(np.asarray(ref_adj), 0).all()
        # the structural rows really ran: (3n/4 unreachable) x n distances
        assert ref_nd == (3 * n // 4) * n
        # a tiny budget forces many tiles plus a padded tail; bit-exact
        monkeypatch.setenv("REPRO_REPAIR_TILE", str(5 * n))
        t_adj, t_d, _, _, _, t_nd, _ = self._repair(data, adj0, adj0_d)
        np.testing.assert_array_equal(np.asarray(t_adj), np.asarray(ref_adj))
        np.testing.assert_array_equal(np.asarray(t_d), np.asarray(ref_d))
        assert t_nd == ref_nd
        assert bfs_reachable(np.asarray(t_adj), 0).all()


class TestNoPrivateCrossImports:
    def test_no_underscore_imports_from_hnsw(self):
        """The refactor's contract: the batched machinery is public engine
        API; nothing imports underscore-private names across modules."""
        root = pathlib.Path(__file__).resolve().parents[1]
        pattern = re.compile(
            r"from\s+repro\.graph\.(hnsw|engine|beam|select)\s+import\s+[^#\n]*"
            r"(?<![\w])_[a-z]"
        )
        offenders = []
        for py in (root / "src").rglob("*.py"):
            text = py.read_text()
            for line in text.splitlines():
                if pattern.search(line):
                    offenders.append(f"{py}: {line.strip()}")
        for py in (root / "benchmarks").rglob("*.py"):
            for line in py.read_text().splitlines():
                if "from repro.graph.hnsw import _" in line:
                    offenders.append(f"{py}: {line.strip()}")
        assert not offenders, "\n".join(offenders)
