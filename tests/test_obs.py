"""Tests for the unified observability layer (DESIGN.md §14).

Contracts:
  1. Registry primitives are exact under contention: counters hammered by
     many threads lose no increments, snapshots taken mid-update are
     consistent views (never errors), and get-or-create returns the same
     object for the same (name, labels) identity.
  2. ``Histogram.pcts_ms`` is bit-identical with the ``np.percentile``
     form the two deleted ``_pcts`` helpers computed — the serve
     ``stats()`` surfaces must not move.
  3. Spans nest per thread, fold CostAccount-style costs in, export as
     JSON lines, and cost nothing when disabled (NULL_SPAN; nothing
     recorded, inputs never ``float()``-ed).
  4. A build's per-phase distance split partitions ``n_dists`` exactly,
     for both incremental and bulk strategies.
  5. The serve stats surfaces stay registry-backed and API-compatible:
     ``latency_window`` is a ctor knob, ``reset()`` exists on every
     stats() provider, and live Runtime counters agree with the registry
     series under concurrent submit threads + the scheduler thread.
"""

from __future__ import annotations

import io
import json
import threading

import numpy as np
import pytest

from repro import obs, serve
from repro.graph.hnsw import HNSWParams
from repro.index import AnnIndex
from repro.obs import report
from tests.conftest import make_clustered

PARAMS = HNSWParams(r_upper=4, r_base=8, ef=16, batch=32, max_layers=2)
FLASH_KW = dict(d_f=32, m_f=16, l_f=4, h=8, kmeans_iters=5)


@pytest.fixture()
def obs_on():
    """Enable obs for one test, restoring the prior global state."""
    was = obs.enabled()
    obs.enable()
    obs.clear_spans()
    yield
    obs.clear_spans()
    obs.enable() if was else obs.disable()


@pytest.fixture()
def obs_off():
    was = obs.enabled()
    obs.disable()
    yield
    obs.enable() if was else obs.disable()


@pytest.fixture(scope="module")
def small_index():
    data = make_clustered(300, 32, seed=3)
    return AnnIndex.build(data, algo="hnsw", backend="fp32", params=PARAMS)


class TestRegistry:
    def test_counter_identity_and_inc(self):
        reg = obs.MetricsRegistry()
        c1 = reg.counter("x_total", route="a")
        c2 = reg.counter("x_total", route="a")
        assert c1 is c2  # get-or-create is idempotent
        c1.inc().inc(4)
        assert c2.value == 5
        c1.reset()
        assert c1.value == 0

    def test_label_order_is_identity_free(self):
        reg = obs.MetricsRegistry()
        assert reg.counter("y", a="1", b="2") is reg.counter("y", b="2", a="1")
        assert reg.counter("y", a="1", b="2").key == 'y{a="1",b="2"}'

    def test_kind_mismatch_raises(self):
        reg = obs.MetricsRegistry()
        reg.counter("z_total")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("z_total")

    def test_gauge_set_and_inc(self):
        g = obs.MetricsRegistry().gauge("depth")
        g.set(7)
        assert g.value == 7
        g.inc(-2)
        assert g.value == 5

    def test_histogram_window_bound_and_alltime(self):
        h = obs.MetricsRegistry().histogram("lat", window=8)
        for i in range(20):
            h.observe(i * 1e-3)
        assert len(h) == 8  # bounded reservoir
        assert h.count == 20  # all-time count survives eviction
        assert h.sum == pytest.approx(sum(i * 1e-3 for i in range(20)))
        np.testing.assert_allclose(
            h.values(), [i * 1e-3 for i in range(12, 20)]
        )

    def test_pcts_bit_identical_with_np_percentile(self):
        # the deleted serve/_pcts helpers were exactly this expression;
        # stats() surfaces must not move by a single ulp
        rng = np.random.default_rng(0)
        vals = rng.exponential(0.01, size=137)
        h = obs.MetricsRegistry().histogram("lat", window=4096)
        for v in vals:
            h.observe(v)
        lat = np.asarray(vals, np.float64)
        expect = (
            float(np.percentile(lat, 50) * 1e3),
            float(np.percentile(lat, 99) * 1e3),
        )
        assert h.pcts_ms() == expect
        assert obs.pcts_ms(vals) == expect
        assert obs.pcts_ms([]) == (0.0, 0.0)

    def test_snapshot_shape_and_reset_keeps_identity(self):
        reg = obs.MetricsRegistry()
        c = reg.counter("a_total", k="v").inc(3)
        reg.gauge("b").set(2)
        reg.histogram("c", window=4).observe(0.5)
        snap = reg.snapshot()
        assert snap["counters"]['a_total{k="v"}'] == 3
        assert snap["gauges"]["b"] == 2
        hsnap = snap["histograms"]["c"]
        assert hsnap["count"] == 1 and hsnap["window"] == 4
        assert set(hsnap) == {
            "count", "sum", "window_len", "window", "p50_ms", "p99_ms"
        }
        reg.reset()
        assert c is reg.counter("a_total", k="v")  # identity survives reset
        assert c.value == 0
        reg.clear()
        assert reg.snapshot() == {
            "counters": {}, "gauges": {}, "histograms": {}
        }

    def test_next_instance_is_unique(self):
        reg = obs.MetricsRegistry()
        ids = [reg.next_instance() for _ in range(10)]
        assert len(set(ids)) == 10


class TestConcurrency:
    def test_counter_exact_under_thread_contention(self):
        c = obs.MetricsRegistry().counter("hammer_total")
        n_threads, n_incs = 8, 2000

        def hammer():
            for _ in range(n_incs):
                c.inc()

        threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == n_threads * n_incs  # no lost increments

    def test_snapshot_during_updates_is_consistent(self):
        reg = obs.MetricsRegistry()
        h = reg.histogram("lat", window=64)
        c = reg.counter("events_total")
        stop = threading.Event()
        errors: list = []

        def writer():
            i = 0
            while not stop.is_set():
                h.observe(i * 1e-4)
                c.inc()
                i += 1

        def reader():
            try:
                while not stop.is_set():
                    snap = reg.snapshot()
                    hs = snap["histograms"]["lat"]
                    # the windowed copy is internally consistent
                    assert hs["window_len"] <= 64
                    assert hs["count"] >= hs["window_len"]
                    h.pcts_ms()
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        ts = [threading.Thread(target=writer) for _ in range(2)]
        ts += [threading.Thread(target=reader) for _ in range(2)]
        for t in ts:
            t.start()
        stop.wait(0.5)
        stop.set()
        for t in ts:
            t.join()
        assert not errors

    def test_runtime_scheduler_thread_agrees_with_registry(self, small_index):
        """Client threads + the Runtime scheduler thread hammer the same
        admission counters; stats() and the registry series must agree."""
        queries = make_clustered(32, 32, seed=4)
        with serve.Runtime(
            small_index, k=5, ef=16, q_buckets=(1, 8), max_wait_ms=2.0
        ) as rt:
            rt.warmup()
            futs: list = []
            futs_lock = threading.Lock()

            def client(chunk):
                for q in chunk:
                    f = rt.submit(q)
                    with futs_lock:
                        futs.append(f)

            threads = [
                threading.Thread(target=client, args=(queries[i::4],))
                for i in range(4)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for f in futs:
                f.result(60)
            stats = rt.stats()
        assert stats["admitted"] == len(queries)
        assert stats["served"] == len(queries)
        assert stats["shed"] == 0
        # the stats() view is the registry series, not a parallel book
        ctl = rt.admission
        assert int(ctl._counters["admitted"].value) == stats["admitted"]
        assert int(ctl._counters["served"].value) == stats["served"]
        assert len(ctl._e2e_lat) == stats["served"]


class TestSpans:
    def test_nesting_and_cost_folding(self, obs_on):
        with obs.span("outer", algo="hnsw") as sp_out:
            with obs.span("inner") as sp_in:
                sp_in.add_cost(np.float32(10.0), 2)  # device-ish scalar ok
            sp_out.set(extra=1)
        roots = obs.spans("outer")
        assert len(roots) == 1
        out = roots[0]
        assert out.attrs == {"algo": "hnsw", "extra": 1}
        assert [c.name for c in out.children] == ["inner"]
        assert out.children[0].n_dists == 10.0
        assert out.children[0].n_hops == 2.0
        assert out.dur_s >= out.children[0].dur_s >= 0.0
        # iter_spans walks descendants too
        assert [s.name for s in obs.iter_spans()] == ["outer", "inner"]

    def test_disabled_records_nothing(self, obs_off):
        class Unfloatable:
            def __float__(self):
                raise AssertionError("disabled add_cost must not float()")

        with obs.span("ghost") as sp:
            assert sp is obs.NULL_SPAN
            sp.add_cost(Unfloatable())  # no sync / no conversion
            sp.set(x=1)
        assert obs.spans("ghost") == []

    def test_export_jsonl(self, obs_on):
        with obs.span("a"):
            with obs.span("b"):
                pass
        with obs.span("c"):
            pass
        buf = io.StringIO()
        assert obs.export_jsonl(buf) == 2  # root spans only
        lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
        assert [ln["name"] for ln in lines] == ["a", "c"]
        assert [c["name"] for c in lines[0]["children"]] == ["b"]
        obs.clear_spans()
        assert obs.spans() == []

    def test_tick_is_gated(self, obs_on):
        obs.tick("gated_total", n=2, route="x")
        assert obs.REGISTRY.counter("gated_total", route="x").value == 2
        obs.disable()
        obs.tick("gated_total", n=100, route="x")
        obs.enable()
        assert obs.REGISTRY.counter("gated_total", route="x").value == 2


class TestBuildPhases:
    @pytest.mark.parametrize("strategy", ["incremental", "bulk"])
    def test_phase_split_partitions_n_dists_exactly(self, strategy, obs_on):
        data = make_clustered(400, 32, seed=5)
        idx = AnnIndex.build(
            data, algo="hnsw", strategy=strategy, params=PARAMS,
            backend_kwargs=FLASH_KW,
        )
        stats = idx.last_stats
        assert stats.phases is not None
        phases = np.asarray(stats.phases, np.float64)
        assert float(phases.sum()) == float(stats.n_dists)  # exact, not ≈
        if strategy == "bulk":
            assert phases[3] > 0  # bulk phase did the work
        else:
            assert phases[2] > 0  # base-layer beam did the work
        # the build span recorded the same totals
        roots = obs.spans("build")
        assert roots and roots[-1].n_dists == float(stats.n_dists)
        assert roots[-1].attrs["strategy"] == strategy


class TestStatsSurfaces:
    def test_engine_latency_window_is_ctor_knob(self, small_index):
        engine = serve.SearchEngine(
            small_index, k=5, ef=16, q_buckets=(1,), latency_window=16
        )
        assert engine.latency_window == 16
        assert engine._lat.window == 16
        q = make_clustered(1, 32, seed=6)[0]
        for _ in range(20):
            engine.search(q)
        assert len(engine._lat) == 16  # bounded by the ctor knob
        assert engine.stats()["calls"] == 20

    def test_reset_on_every_stats_provider(self, small_index):
        engine = serve.SearchEngine(
            small_index, k=5, ef=16, q_buckets=(1,), latency_window=8
        )
        q = make_clustered(1, 32, seed=7)[0]
        engine.search(q)
        n_compiles = engine.n_compiles
        engine.reset()
        stats = engine.stats()
        assert stats["calls"] == 0 and stats["p50_ms"] == 0.0
        assert engine.n_compiles == n_compiles  # compiles survive reset

        ctl = serve.AdmissionController()
        ctl.admit(0)
        ctl.record_served(1e-3, 2e-3, missed=False)
        ctl.reset()
        stats = ctl.stats()
        assert stats["admitted"] == 0 and stats["served"] == 0
        assert stats["p50_ms"] == 0.0

        with serve.Runtime(
            small_index, k=5, ef=16, q_buckets=(1,), max_wait_ms=2.0
        ) as rt:
            rt.warmup()
            rt.search(q, 60)
            assert rt.stats()["served"] == 1
            rt.reset()
            assert rt.stats()["served"] == 0
            assert rt.stats()["cold_dispatches"] == 0

    def test_flip_spans_and_counter(self, small_index, obs_on):
        handle = serve.IndexHandle(small_index.clone())
        flips_before = obs.REGISTRY.counter("serve_flips_total").value
        gen = handle.add(make_clustered(4, 32, seed=8))
        assert gen.gen == 1
        assert obs.REGISTRY.counter("serve_flips_total").value == (
            flips_before + 1
        )
        sp = obs.spans("serve/flip")[-1]
        assert sp.attrs["base_gen"] == 0 and sp.attrs["gen"] == 1
        names = [c.name for c in sp.children]
        assert names == [
            "serve/flip/clone", "serve/flip/apply", "serve/flip/prepare"
        ]


class TestReport:
    def test_prometheus_text_rendering(self):
        snap = {
            "counters": {'req_total{route="a"}': 3, "plain_total": 1},
            "gauges": {"depth": 2},
            "histograms": {
                'lat_seconds{inst="0"}': {
                    "count": 5, "sum": 0.5, "window_len": 5,
                    "window": 4096, "p50_ms": 10.0, "p99_ms": 90.0,
                },
            },
        }
        text = report.prometheus_text(snap)
        assert 'req_total{route="a"} 3' in text
        assert "plain_total 1" in text
        assert "depth 2" in text
        assert 'lat_seconds_count{inst="0"} 5' in text
        assert 'lat_seconds_ms{inst="0",quantile="0.5"} 10.0' in text
        assert text.endswith("\n")

    def test_phase_table_exactness_line(self):
        class Stub:
            n_dists = 100.0
            phases = np.asarray([10.0, 20.0, 70.0, 0.0, 0.0])

        table = report.phase_table(Stub())
        assert "exact partition: True" in table
        assert "beam_base" in table

        class Bad:
            n_dists = 100.0
            phases = np.asarray([10.0, 20.0, 60.0, 0.0, 0.0])

        assert "exact partition: False" in report.phase_table(Bad())

    def test_json_dump_structure(self, obs_on):
        obs.counter("dump_total", k="v").inc()
        with obs.span("dump_span"):
            pass
        out = report.json_dump()
        assert 'dump_total{k="v"}' in out["metrics"]["counters"]
        assert any(sp["name"] == "dump_span" for sp in out["spans"])
