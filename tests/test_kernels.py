"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Every kernel is swept across shapes and dtypes; integer-output kernels must
match exactly, float kernels within tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def _rng(seed=0):
    return np.random.default_rng(seed)


class TestFlashScan:
    @pytest.mark.parametrize("n", [1, 7, 128, 1000, 1024, 2050])
    @pytest.mark.parametrize("m", [4, 16])
    def test_shapes_exact(self, n, m):
        rng = _rng(n * 31 + m)
        codes = jnp.asarray(rng.integers(0, 16, (n, m)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (m, 16)), jnp.int32)
        out_ref = ref.flash_scan_ref(codes, adt)
        out = ops.flash_scan(codes, adt, impl="interpret")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))

    @pytest.mark.parametrize("k", [16, 64, 256])
    def test_k_sweep(self, k):
        """K up to 256 — covers PQ-style (L=8) tables, not just Flash (L=4)."""
        rng = _rng(k)
        codes = jnp.asarray(rng.integers(0, k, (300, 8)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (8, k)), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(ops.flash_scan(codes, adt, impl="interpret")),
            np.asarray(ref.flash_scan_ref(codes, adt)),
        )

    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
    def test_dtype_sweep(self, dtype):
        rng = _rng(3)
        codes = jnp.asarray(rng.integers(0, 16, (257, 16)), jnp.int32)
        adt = jnp.asarray(rng.uniform(0, 250, (16, 16))).astype(dtype)
        out = ops.flash_scan(codes, adt, impl="interpret")
        out_ref = ref.flash_scan_ref(codes, adt)
        assert out.dtype == out_ref.dtype
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(out_ref), rtol=1e-6, atol=1e-4
        )

    @given(st.integers(min_value=1, max_value=300), st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_property_random(self, n, seed):
        rng = _rng(seed)
        codes = jnp.asarray(rng.integers(0, 16, (n, 8)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (8, 16)), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(ops.flash_scan(codes, adt, impl="interpret")),
            np.asarray(ref.flash_scan_ref(codes, adt)),
        )

    def test_block_sizes(self):
        rng = _rng(9)
        codes = jnp.asarray(rng.integers(0, 16, (700, 16)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (16, 16)), jnp.int32)
        expect = np.asarray(ref.flash_scan_ref(codes, adt))
        for bn in (128, 256, 1024):
            got = ops.flash_scan(codes, adt, impl="interpret", block_n=bn)
            np.testing.assert_array_equal(np.asarray(got), expect)


class TestFlashScanBlocked:
    @pytest.mark.parametrize("g,b", [(1, 16), (5, 16), (16, 128), (33, 32)])
    def test_blocked_layout(self, g, b):
        rng = _rng(g * b)
        blocks = jnp.asarray(rng.integers(0, 16, (g, 16, b)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (16, 16)), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(ops.flash_scan_blocked(blocks, adt, impl="interpret")),
            np.asarray(ref.flash_scan_blocked_ref(blocks, adt)),
        )

    def test_blocked_equals_flat(self):
        """Blocked layout (§3.3.4) computes the same distances as flat."""
        from repro.core import to_neighbor_blocks

        rng = _rng(4)
        codes = jnp.asarray(rng.integers(0, 16, (64, 16)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (16, 16)), jnp.int32)
        flat = ref.flash_scan_ref(codes, adt)
        blocks = to_neighbor_blocks(codes, 16)  # (4, 16, 16)
        blocked = ops.flash_scan_blocked(blocks, adt, impl="interpret")
        np.testing.assert_array_equal(
            np.asarray(blocked).reshape(-1), np.asarray(flat)
        )

    @pytest.mark.parametrize("w,r", [(1, 16), (4, 16), (8, 32)])
    @pytest.mark.parametrize("impl", ["ref", "interpret"])
    def test_batch_rows_equal_flat(self, w, r, impl):
        """flash_scan_batch (the multi-expansion beam's W·R entry point)
        equals the flat scan row-by-row, on both dispatch paths."""
        rng = _rng(w * r)
        rows = jnp.asarray(rng.integers(0, 16, (w, r, 16)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (16, 16)), jnp.int32)
        got = ops.flash_scan_batch(rows, adt, impl=impl)
        expect = ref.flash_scan_ref(rows.reshape(w * r, 16), adt).reshape(w, r)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


class TestFlashRound:
    """Bulk refinement-round scan (DESIGN.md §12): per-row batched tables."""

    @pytest.mark.parametrize("b,c", [(1, 8), (7, 33), (8, 288), (50, 40)])
    def test_shapes_exact(self, b, c):
        rng = _rng(b * 131 + c)
        codes = jnp.asarray(rng.integers(0, 16, (b, c, 16)), jnp.int32)
        adts = jnp.asarray(rng.integers(0, 255, (b, 16, 16)), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(ops.flash_round(codes, adts, impl="interpret")),
            np.asarray(ref.flash_round_ref(codes, adts)),
        )

    def test_rows_equal_flat_scan(self):
        """Each row is exactly flash_scan against that row's own table."""
        rng = _rng(6)
        b, c, m = 5, 24, 8
        codes = jnp.asarray(rng.integers(0, 16, (b, c, m)), jnp.int32)
        adts = jnp.asarray(rng.integers(0, 255, (b, m, 16)), jnp.int32)
        got = np.asarray(ops.flash_round(codes, adts, impl="ref"))
        for i in range(b):
            np.testing.assert_array_equal(
                got[i], np.asarray(ref.flash_scan_ref(codes[i], adts[i]))
            )

    def test_float_tables_close(self):
        """f32 tables: one-hot select-sum vs gather-sum may differ in
        accumulation order — allclose, not bit-equal (int32, the Flash
        production dtype, is exact above)."""
        rng = _rng(7)
        codes = jnp.asarray(rng.integers(0, 16, (9, 30, 16)), jnp.int32)
        adts = jnp.asarray(rng.uniform(0, 250, (9, 16, 16)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.flash_round(codes, adts, impl="interpret")),
            np.asarray(ref.flash_round_ref(codes, adts)),
            rtol=1e-5, atol=1e-3,
        )

    def test_block_b_sweep(self):
        rng = _rng(8)
        codes = jnp.asarray(rng.integers(0, 16, (21, 40, 16)), jnp.int32)
        adts = jnp.asarray(rng.integers(0, 255, (21, 16, 16)), jnp.int32)
        expect = np.asarray(ref.flash_round_ref(codes, adts))
        for bb in (1, 4, 16):
            got = ops.flash_round(codes, adts, impl="interpret", block_b=bb)
            np.testing.assert_array_equal(np.asarray(got), expect)

    def test_shape_mismatch_raises(self):
        codes = jnp.zeros((4, 8, 16), jnp.int32)
        adts = jnp.zeros((5, 16, 16), jnp.int32)
        with pytest.raises(ValueError, match="codes"):
            ops.flash_round(codes, adts, impl="interpret")


class TestL2Batch:
    @pytest.mark.parametrize(
        "n,c,d", [(1, 1, 4), (17, 33, 48), (256, 256, 128), (300, 70, 130)]
    )
    def test_shapes(self, n, c, d):
        rng = _rng(n + c + d)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(c, d)), jnp.float32)
        got = ops.l2_batch(x, y, impl="interpret")
        want = ref.l2_batch_ref(x, y)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = _rng(11)
        x = jnp.asarray(rng.normal(size=(64, 32))).astype(dtype)
        y = jnp.asarray(rng.normal(size=(32, 32))).astype(dtype)
        got = ops.l2_batch(x, y, impl="interpret")
        want = ref.l2_batch_ref(x, y)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=tol, atol=tol * 10
        )

    def test_self_distance_zero(self):
        rng = _rng(5)
        x = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        d = ops.l2_batch(x, x, impl="interpret")
        assert float(jnp.max(jnp.abs(jnp.diagonal(d)))) < 1e-3


class TestSqL2:
    @pytest.mark.parametrize("n,d", [(1, 8), (100, 64), (513, 100), (2048, 256)])
    def test_shapes(self, n, d):
        rng = _rng(n + d)
        q = jnp.asarray(rng.integers(0, 256, (d,)), jnp.int32)
        db = jnp.asarray(rng.integers(0, 256, (n, d)), jnp.int32)
        s2 = jnp.asarray(rng.uniform(1e-4, 0.1, (d,)), jnp.float32)
        got = ops.sq_l2(q, db, s2, impl="interpret")
        want = ref.sq_l2_ref(q, db, s2)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3
        )

    def test_matches_core_sq_dist(self, small_data):
        """Kernel path == core.sq_dist == decoded-space distance."""
        from repro import core

        data, _ = small_data
        sq = core.fit_sq(data, bits=8)
        qc = core.sq_encode(sq, data[0:1])[0]
        dbc = core.sq_encode(sq, data[:100])
        got = ops.sq_l2(qc, dbc, sq.s2, impl="interpret")
        want = core.sq_dist(sq, qc[None, :], dbc)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3
        )


class TestDispatch:
    def test_auto_resolves_on_cpu(self):
        assert ops.resolve_impl("auto") == "ref"

    def test_override(self):
        ops.set_default_impl("interpret")
        try:
            assert ops.resolve_impl("auto") == "interpret"
        finally:
            ops.set_default_impl(None)

    def test_bad_impl_raises(self):
        with pytest.raises(ValueError):
            ops.resolve_impl("cuda")
