"""Tests for the continuous-batching serving runtime (DESIGN.md §13).

Contracts:
  1. ``AnnIndex.clone`` is a fully independent copy: mutations on the clone
     never touch the source (arrays, tombstones, or search results).
  2. ``IndexHandle`` is RCU: generation numbers are monotonic, published
     generations are immutable (a pinned generation keeps serving its
     snapshot bit-exactly across later flips), a raising mutation publishes
     nothing, and prepare hooks see the clone before readers can.
  3. ``SearchEngine`` serves pinned generations through the same executable
     table (``view=``) and rebinds across flips (``refresh(index=…)``)
     with zero steady-state recompiles for shape-preserving flips.
  4. ``Runtime`` packs coalesced requests bit-identically to a direct
     batched search, drains on close, rejects at the door (queue depth),
     sheds expired deadlines before compute, and keeps the admission
     arithmetic exact: ``admitted == served + shed + pending``.
  5. The RCU stress test: a mutator continuously flipping generations
     (each flip atomically add-new-sentinel + delete-old-sentinel) races
     reader threads; every result set must be consistent with exactly one
     published generation — exactly one live sentinel visible, never two
     (half-applied add) and never the torn orderings in between.
"""

from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro import serve
from repro.graph.hnsw import HNSWParams
from repro.index import AnnIndex, SearchSpec
from repro.serve.admission import AdmissionConfig, AdmissionController
from tests.conftest import make_clustered

PARAMS = HNSWParams(r_upper=4, r_base=8, ef=16, batch=32, max_layers=2)
N_BASE, N_GROW, N_Q = 240, 24, 16
DIM = 16


@pytest.fixture(scope="module")
def runtime_data():
    x = make_clustered(N_BASE + N_GROW + N_Q, DIM, n_clusters=12, seed=11)
    x = np.asarray(x, np.float32)
    return (
        x[:N_BASE],
        x[N_BASE:N_BASE + N_GROW],
        x[N_BASE + N_GROW:],
    )


@pytest.fixture(scope="module")
def fp32_idx(runtime_data):
    data, _, _ = runtime_data
    return AnnIndex.build(data, algo="hnsw", backend="fp32", params=PARAMS)


class TestClone:
    def test_clone_is_independent(self, runtime_data, fp32_idx):
        _, growth, queries = runtime_data
        before = np.asarray(fp32_idx.search(queries, k=5, ef=24).ids)
        clone = fp32_idx.clone()
        clone.add(growth)
        clone.delete([0, 1, 2])
        assert clone.n == fp32_idx.n + N_GROW
        assert fp32_idx.n == N_BASE, "clone mutation leaked into the source"
        assert fp32_idx.deleted_ids.size == 0
        after = np.asarray(fp32_idx.search(queries, k=5, ef=24).ids)
        np.testing.assert_array_equal(before, after)

    def test_clone_searches_bit_identically(self, runtime_data, fp32_idx):
        _, _, queries = runtime_data
        clone = fp32_idx.clone()
        a = fp32_idx.search(queries, k=5, ef=24)
        b = clone.search(queries, k=5, ef=24)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


class TestIndexHandle:
    def test_rejects_snapshotless_objects(self):
        with pytest.raises(TypeError, match="export_state"):
            serve.IndexHandle(object())

    def test_flips_are_monotonic_and_immutable(self, runtime_data, fp32_idx):
        _, growth, queries = runtime_data
        handle = serve.IndexHandle(fp32_idx)
        g0 = handle.current
        assert g0.gen == 0 and g0.index is fp32_idx
        before = np.asarray(g0.index.search(queries, k=5, ef=24).ids)

        g1 = handle.add(growth)
        assert g1.gen == 1 and handle.current is g1
        assert g1.index is not fp32_idx
        assert g1.index.n == N_BASE + N_GROW

        victim = int(before[0, 0])
        g2 = handle.delete([victim])
        assert g2.gen == 2 and handle.generation == 2
        assert bool(g2.banned[victim])

        # published generations never mutate: gen-0 still serves the
        # original snapshot bit-exactly, nothing banned, original n
        assert g0.index.n == N_BASE
        assert not bool(g0.banned.any())
        after = np.asarray(g0.index.search(queries, k=5, ef=24).ids)
        np.testing.assert_array_equal(before, after)
        # and gen-1 (pinned mid-history) never saw the delete
        assert not bool(g1.banned[victim])

    def test_raising_mutation_publishes_nothing(self, fp32_idx):
        handle = serve.IndexHandle(fp32_idx)

        def bad(index):
            index.delete([0])
            raise RuntimeError("boom")

        with pytest.raises(RuntimeError, match="boom"):
            handle.mutate(bad)
        assert handle.generation == 0
        assert handle.current.index is fp32_idx
        assert fp32_idx.deleted_ids.size == 0

    def test_prepare_hook_runs_before_flip(self, runtime_data, fp32_idx):
        _, growth, _ = runtime_data
        handle = serve.IndexHandle(fp32_idx)
        seen = []

        def hook(gen):
            # the clone is fully built but not yet published
            seen.append((gen.gen, gen.index.n, handle.generation))

        handle.on_prepare(hook)
        handle.add(growth)
        assert seen == [(1, N_BASE + N_GROW, 0)]


class TestEngineViews:
    def test_view_parity_and_refresh_keeps_executables(
        self, runtime_data, fp32_idx
    ):
        _, growth, queries = runtime_data
        engine = serve.SearchEngine(
            fp32_idx, k=5, ef=24, q_buckets=(1, 8)
        ).warmup()
        handle = serve.IndexHandle(fp32_idx)
        g0 = handle.current
        g1 = handle.add(growth)

        # a grown generation retraces once per bucket — paid via warm_view
        # off the request path — then serves warm
        engine.warm_view(g1)
        n_compiles = engine.n_compiles
        res = engine.search(queries[:8], view=g1)
        assert engine.n_compiles == n_compiles
        direct = g1.index.search(queries[:8], k=5, ef=24)
        np.testing.assert_array_equal(
            np.asarray(res.ids), np.asarray(direct.ids)
        )

        # the pinned old generation still serves through the same engine
        res0 = engine.search(queries[:8], view=g0)
        direct0 = fp32_idx.search(queries[:8], k=5, ef=24)
        np.testing.assert_array_equal(
            np.asarray(res0.ids), np.asarray(direct0.ids)
        )

        # rebinding the default index across the flip keeps every compiled
        # executable: serving the new generation costs zero further traces
        engine.refresh(index=g1.index)
        engine.search(queries[:8])
        engine.search(queries[0])
        assert engine.n_compiles == n_compiles

    def test_shape_preserving_flip_is_compile_free(
        self, runtime_data, fp32_idx
    ):
        _, _, queries = runtime_data
        engine = serve.SearchEngine(
            fp32_idx, k=5, ef=24, q_buckets=(1, 8)
        ).warmup()
        handle = serve.IndexHandle(fp32_idx)
        n_compiles = engine.n_compiles
        g1 = handle.delete([3, 4])
        engine.warm_view(g1)  # no-op: same shapes
        engine.refresh(index=g1.index)
        res = engine.search(queries[:8])
        assert engine.n_compiles == n_compiles, (
            "delete flip recompiled despite unchanged array shapes"
        )
        ids = np.asarray(res.ids)
        assert 3 not in ids and 4 not in ids


class TestRuntime:
    def test_packed_results_match_direct_batch(self, runtime_data, fp32_idx):
        _, _, queries = runtime_data
        with serve.Runtime(
            fp32_idx, k=5, ef=24, q_buckets=(1, 8), max_wait_ms=100.0
        ) as rt:
            rt.warmup()
            futs = [rt.submit(queries[i]) for i in range(12)]
            results = [f.result(timeout=30) for f in futs]
            direct = np.asarray(
                rt.engine.search(queries[:12], record=False).ids
            )
            for i, res in enumerate(results):
                np.testing.assert_array_equal(np.asarray(res.ids), direct[i])
                assert float(res.n_dists) > 0
            stats = rt.stats()
        assert stats["requests"] == 12
        assert stats["batches"] < 12, "nothing was coalesced"
        assert stats["max_batch_seen"] >= 2
        assert stats["admitted"] == 12
        assert stats["served"] == 12
        assert stats["shed"] == stats["rejected"] == 0
        assert stats["cold_dispatches"] == 0
        assert stats["p99_ms"] >= stats["p50_ms"] >= 0.0

    def test_drain_on_close(self, runtime_data, fp32_idx):
        _, _, queries = runtime_data
        rt = serve.Runtime(
            fp32_idx, k=5, ef=24, q_buckets=(1, 8), max_wait_ms=2000.0
        ).warmup()
        futs = [rt.submit(queries[i]) for i in range(6)]
        rt.close()  # must serve all six, not abandon them
        for f in futs:
            assert f.done()
            assert f.result(0).ids.shape == (5,)
        stats = rt.stats()
        assert stats["served"] == 6
        assert stats["admitted"] == stats["served"] + stats["shed"]
        assert stats["pending"] == 0
        with pytest.raises(RuntimeError, match="closed"):
            rt.submit(queries[0])
        with pytest.raises(RuntimeError, match="closed"):
            rt.add(queries[:2])

    def test_submit_validates_single_query(self, runtime_data, fp32_idx):
        _, _, queries = runtime_data
        with serve.Runtime(fp32_idx, k=5, ef=24, q_buckets=(1,)) as rt:
            with pytest.raises(ValueError, match="single"):
                rt.submit(queries[:2])

    def test_queue_depth_rejects_at_the_door(self, runtime_data, fp32_idx):
        _, _, queries = runtime_data
        with serve.Runtime(
            fp32_idx, k=5, ef=24, q_buckets=(1,), max_queue=0
        ) as rt:
            with pytest.raises(serve.QueueFullError, match="queue full"):
                rt.submit(queries[0])
            stats = rt.stats()
        assert stats["rejected"] == 1
        assert stats["admitted"] == 0, "a rejected request was admitted"

    def test_expired_deadline_sheds_before_compute(
        self, runtime_data, fp32_idx
    ):
        _, _, queries = runtime_data
        with serve.Runtime(
            fp32_idx, k=5, ef=24, q_buckets=(1, 8), max_wait_ms=50.0
        ) as rt:
            rt.warmup()
            rt.reset_stats()
            dead = rt.submit(queries[0], deadline_ms=0.0)
            with pytest.raises(serve.DeadlineExceededError):
                dead.result(timeout=30)
            live = [rt.submit(q) for q in queries[1:5]]
            for f in live:
                assert f.result(timeout=30).ids.shape == (5,)
            stats = rt.stats()
        assert stats["shed"] == 1
        assert stats["served"] == 4
        assert stats["admitted"] == stats["served"] + stats["shed"]
        assert stats["shed_rate"] == pytest.approx(1 / 5)
        assert stats["cold_dispatches"] == 0

    def test_mutations_flip_generations_and_stay_warm(
        self, runtime_data, fp32_idx
    ):
        _, growth, queries = runtime_data
        with serve.Runtime(
            fp32_idx, k=5, ef=24, q_buckets=(1, 8), max_wait_ms=5.0
        ) as rt:
            rt.warmup()
            assert rt.generation == 0
            rt.add(growth).result(timeout=120)
            assert rt.generation == 1
            assert rt.engine.index.n == N_BASE + N_GROW
            assert fp32_idx.n == N_BASE, "runtime mutated the live index"

            # the grown generation was pre-warmed on the mutator thread:
            # searches after the flip hit only compiled executables
            r = rt.search(queries[0], 30)
            assert r.ids.shape == (5,)
            victim = int(np.asarray(r.ids)[0])
            n_compiles = rt.engine.n_compiles

            rt.delete([victim]).result(timeout=120)
            assert rt.generation == 2
            ids = np.asarray(rt.search(queries[0], 30).ids)
            assert victim not in ids
            rt.compact().result(timeout=120)
            assert rt.generation == 3
            ids = np.asarray(rt.search(queries[0], 30).ids)
            assert victim not in ids
            stats = rt.stats()
            # delete + compact preserve array shapes: zero recompiles, and
            # no request ever hit a cold executable
            assert rt.engine.n_compiles == n_compiles
        assert stats["cold_dispatches"] == 0
        assert stats["generation"] == 3

    def test_atomic_multi_op_mutation(self, runtime_data, fp32_idx):
        _, growth, _ = runtime_data
        with serve.Runtime(fp32_idx, k=5, ef=24, q_buckets=(1,)) as rt:
            gen_before = rt.generation

            def swap(index):
                index.add(growth[:1])
                return index.delete([0])

            ndel = rt.mutate(swap).result(timeout=120)
            assert ndel == 1
            # add + delete landed as ONE generation flip
            assert rt.generation == gen_before + 1
            gen = rt.handle.current
            assert gen.index.n == N_BASE + 1
            assert bool(gen.banned[0])

    def test_failed_mutation_leaves_generation_unchanged(
        self, runtime_data, fp32_idx
    ):
        with serve.Runtime(fp32_idx, k=5, ef=24, q_buckets=(1,)) as rt:
            gen_before = rt.generation

            def bad(index):
                raise ValueError("rejected payload")

            fut = rt.mutate(bad)
            with pytest.raises(ValueError, match="rejected payload"):
                fut.result(timeout=120)
            assert rt.generation == gen_before

    def test_reader_pinned_generation_survives_flips(
        self, runtime_data, fp32_idx
    ):
        """Deterministic snapshot isolation: a generation pinned before a
        delete keeps returning the deleted id; the post-flip generation
        never does."""
        _, _, queries = runtime_data
        with serve.Runtime(
            fp32_idx, k=5, ef=24, q_buckets=(1, 8)
        ) as rt:
            rt.warmup()
            pinned = rt.handle.current
            victim = int(np.asarray(rt.search(queries[0], 30).ids)[0])
            rt.delete([victim]).result(timeout=120)
            # the old snapshot still serves the victim through the shared
            # engine; the current generation bans it
            old = np.asarray(
                rt.engine.search(queries[0], view=pinned, record=False).ids
            )
            new = np.asarray(rt.search(queries[0], 30).ids)
            assert victim in old
            assert victim not in new


class TestRCUStress:
    """Readers race a continuously-flipping mutator.

    Every generation holds exactly ONE live sentinel vector, planted on
    top of the query point (generation g's flip atomically adds sentinel g
    and deletes sentinel g−1). A result set may therefore contain exactly
    one sentinel id:

      * two sentinels  → the reader saw an add without its paired delete
        (half-applied mutation — the bug RCU exists to prevent);
      * zero sentinels → the paired delete without its add (the other
        torn ordering; the sentinel sits ~0 distance from the query, so
        recall cannot miss it);
      * sentinel g−1 after sentinel g was observed by the same thread →
        a generation went backwards.
    """

    G_FLIPS = 4
    READERS = 2

    def test_readers_never_observe_torn_generations(self):
        rng = np.random.default_rng(23)
        corpus = make_clustered(N_BASE, DIM, n_clusters=12, seed=29)
        corpus = np.asarray(corpus, np.float32)
        probe = corpus.mean(axis=0) + 6.0  # offset, but well within reach
        sentinels = probe[None, :] + rng.normal(
            scale=1e-3, size=(self.G_FLIPS + 1, DIM)
        ).astype(np.float32)
        base = np.concatenate([corpus, sentinels[:1]])
        idx = AnnIndex.build(base, algo="hnsw", backend="fp32", params=PARAMS)
        sentinel_ids = set(range(N_BASE, N_BASE + self.G_FLIPS + 1))

        failures: list = []
        observed: list = []
        done = threading.Event()

        def read_loop(tid: int, rt: serve.Runtime) -> None:
            last_seen, i = -1, 0
            # hammer until every flip has published, so reads genuinely
            # overlap the clone/apply/warm/flip cycles
            while not done.is_set():
                res = rt.search(probe, 60)
                ids = [int(v) for v in np.asarray(res.ids)]
                live = [v for v in ids if v in sentinel_ids]
                if len(live) != 1:
                    failures.append(
                        f"reader {tid} read {i}: expected exactly one live "
                        f"sentinel, got {live} in {ids}"
                    )
                elif (g_obs := live[0] - N_BASE) < last_seen:
                    failures.append(
                        f"reader {tid} read {i}: generation went backwards "
                        f"({last_seen} -> {g_obs})"
                    )
                else:
                    last_seen = g_obs
                    observed.append(g_obs)
                i += 1

        with serve.Runtime(
            idx, k=4, ef=24, q_buckets=(1, 8), max_wait_ms=1.0
        ) as rt:
            rt.warmup()
            readers = [
                threading.Thread(target=read_loop, args=(t, rt))
                for t in range(self.READERS)
            ]
            for t in readers:
                t.start()
            try:
                # the mutator: G atomic sentinel swaps racing the readers.
                # Sentinel g's id is deterministic (ids are allocated densely
                # and mutations apply in submit order): N_BASE + g.
                for g in range(1, self.G_FLIPS + 1):
                    def swap(index, g=g):
                        index.add(sentinels[g:g + 1])
                        index.delete([N_BASE + g - 1])

                    rt.mutate(swap).result(timeout=300)
            finally:
                done.set()
            for t in readers:
                t.join(timeout=300)
            assert not any(t.is_alive() for t in readers)
            stats = rt.stats()

        assert not failures, "\n".join(failures)
        # the race was real: reads landed on more than one generation …
        assert len(set(observed)) > 1, (
            f"stress test raced nothing: all reads saw generation "
            f"{set(observed)}"
        )
        # … every flip published while readers were live …
        assert stats["generation"] == self.G_FLIPS
        # … and the books balance across the race
        assert stats["served"] == len(observed) + len(failures)
        assert stats["admitted"] == stats["served"] + stats["shed"]


class TestAdmissionController:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="max_queue"):
            AdmissionConfig(max_queue=-1)
        with pytest.raises(ValueError, match="default_deadline_ms"):
            AdmissionConfig(default_deadline_ms=-5.0)

    def test_deadline_resolution(self):
        ctl = AdmissionController(AdmissionConfig(default_deadline_ms=40.0))
        assert ctl.deadline_for(10.0, now=100.0) == pytest.approx(100.010)
        assert ctl.deadline_for(None, now=100.0) == pytest.approx(100.040)
        assert AdmissionController().deadline_for(None) is None
        with pytest.raises(ValueError, match="deadline_ms"):
            ctl.deadline_for(-1.0)

    def test_shed_and_serve_arithmetic(self):
        ctl = AdmissionController(AdmissionConfig(max_queue=2))
        ctl.admit(0)
        ctl.admit(1)
        with pytest.raises(serve.QueueFullError):
            ctl.admit(2)
        ctl.shed()
        ctl.record_served(0.002, 0.001, missed=True)
        stats = ctl.stats()
        assert stats["admitted"] == 2
        assert stats["rejected"] == 1
        assert stats["shed"] == 1
        assert stats["served"] == 1
        assert stats["deadline_misses"] == 1
        assert stats["admitted"] == stats["served"] + stats["shed"]
        assert stats["shed_rate"] == pytest.approx(0.5)
        assert stats["p50_ms"] == pytest.approx(3.0)
        assert stats["queue_p50_ms"] == pytest.approx(2.0)
        assert stats["service_p50_ms"] == pytest.approx(1.0)
        assert stats["queue_p99_ms"] >= stats["queue_p50_ms"]
        ctl.reset_stats()
        zeroed = ctl.stats()
        assert zeroed["admitted"] == zeroed["served"] == zeroed["shed"] == 0
        assert zeroed["p99_ms"] == 0.0
