"""Fused beam-expansion path (DESIGN.md §10).

Contracts:
  1. Kernel parity: ``ops.flash_expand`` (interpret-mode Pallas) == the
     pure-jnp oracle, over packed and legacy mirrors, with inactive (−1)
     frontier slots.
  2. Beam parity grid: ``beam_search`` with the fused ``expand()`` hook is
     bit-exact with the gather+scan fallback — ids, dists, and both cost
     counters — across width ∈ {1, 4, 8}, ef ∈ {8, 48}, with/without a
     tombstone mask and a warm visited bitmap, on the ref and
     interpret-mode Pallas dispatch paths.
  3. Packed 4-bit mirror: pack→unpack is the identity, the mirror's HBM
     footprint is halved vs unpacked bytes, snapshots round-trip (format
     v2) and legacy unpacked (v1) state migrates bit-exactly.
  4. Capability hook: only the Flash blocked layout advertises ``expand``
     (the CI guard), and forcing ``fused=True`` elsewhere raises.
  5. The single-sort ``_merge`` is bit-identical to the former
     concatenate + ``top_k`` + gather merge.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import graph
from repro.core import flash as flash_mod
from repro.graph import beam as beam_mod
from repro.graph.beam import beam_search, uses_fused_expand
from repro.graph.hnsw import HNSWParams, build_hnsw
from repro.kernels import ops, ref

PARAMS = HNSWParams(r_upper=8, r_base=16, ef=32, batch=16, max_layers=2)
FLASH_KW = dict(d_f=32, m_f=16, l_f=4, h=8, kmeans_iters=8)


def _rng(seed=0):
    return np.random.default_rng(seed)


@pytest.fixture(scope="module")
def blocked_index(small_data, key):
    data, _ = small_data
    be = graph.make_backend(
        "flash_blocked", data, key, r_for_blocked=PARAMS.r_base, **FLASH_KW
    )
    index, _ = build_hnsw(data, be, params=PARAMS)
    return index


# ---------------------------------------------------------------------------
# 1) kernel parity: interpret-mode Pallas vs oracle
# ---------------------------------------------------------------------------


class TestFlashExpandKernel:
    @pytest.mark.parametrize("w", [1, 4, 8])
    @pytest.mark.parametrize("r", [8, 32])
    def test_packed_parity(self, w, r):
        rng = _rng(w * 131 + r)
        n, m, k = 120, 16, 16
        nodes = jnp.asarray(rng.integers(-1, n, (w,)), jnp.int32)
        adj = jnp.asarray(rng.integers(-1, n, (n, r)), jnp.int32)
        mirror = jnp.asarray(rng.integers(0, 256, (n, r, m // 2)), jnp.uint8)
        adt = jnp.asarray(rng.integers(0, 255, (m, k)), jnp.int32)
        rows_i, sums_i = ops.flash_expand(nodes, adj, mirror, adt, impl="interpret")
        rows_r, sums_r = ref.flash_expand_ref(nodes, adj, mirror, adt)
        np.testing.assert_array_equal(np.asarray(rows_i), np.asarray(rows_r))
        np.testing.assert_array_equal(np.asarray(sums_i), np.asarray(sums_r))

    @pytest.mark.parametrize("m", [7, 16])
    def test_matches_unfused_scan_pipeline(self, m):
        """Fused kernel == gather + unpack + flash_scan_batch, end to end."""
        rng = _rng(m)
        n, w, r, k = 90, 4, 16, 16
        codes = jnp.asarray(rng.integers(0, 16, (n, r, m)), jnp.int32)
        mirror = flash_mod.pack_codes(codes)
        nodes = jnp.asarray(rng.integers(0, n, (w,)), jnp.int32)
        adj = jnp.asarray(rng.integers(-1, n, (n, r)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (m, k)), jnp.int32)
        rows, sums = ops.flash_expand(nodes, adj, mirror, adt, impl="interpret")
        expect = ops.flash_scan_batch(codes[nodes], adt, impl="ref")
        np.testing.assert_array_equal(np.asarray(sums), np.asarray(expect))
        np.testing.assert_array_equal(np.asarray(rows), np.asarray(adj[nodes]))

    def test_legacy_unpacked_mirror(self):
        """K > 16 coders keep the (n, R, M) int32 mirror; same kernel."""
        rng = _rng(5)
        n, w, r, m, k = 60, 4, 8, 8, 64
        nodes = jnp.asarray(rng.integers(-1, n, (w,)), jnp.int32)
        adj = jnp.asarray(rng.integers(-1, n, (n, r)), jnp.int32)
        mirror = jnp.asarray(rng.integers(0, k, (n, r, m)), jnp.int32)
        adt = jnp.asarray(rng.integers(0, 255, (m, k)), jnp.int32)
        rows_i, sums_i = ops.flash_expand(nodes, adj, mirror, adt, impl="interpret")
        rows_r, sums_r = ref.flash_expand_ref(nodes, adj, mirror, adt)
        np.testing.assert_array_equal(np.asarray(rows_i), np.asarray(rows_r))
        np.testing.assert_array_equal(np.asarray(sums_i), np.asarray(sums_r))

    def test_float_adt(self):
        """float32 tables (rerank-ordering ADTs) go through the same path."""
        rng = _rng(7)
        n, w, r, m, k = 50, 2, 8, 16, 16
        nodes = jnp.asarray(rng.integers(0, n, (w,)), jnp.int32)
        adj = jnp.asarray(rng.integers(-1, n, (n, r)), jnp.int32)
        mirror = jnp.asarray(rng.integers(0, 256, (n, r, m // 2)), jnp.uint8)
        adt = jnp.asarray(rng.uniform(0, 100, (m, k)), jnp.float32)
        _, sums_i = ops.flash_expand(nodes, adj, mirror, adt, impl="interpret")
        _, sums_r = ref.flash_expand_ref(nodes, adj, mirror, adt)
        assert sums_i.dtype == sums_r.dtype == jnp.float32
        np.testing.assert_allclose(
            np.asarray(sums_i), np.asarray(sums_r), rtol=1e-6, atol=1e-4
        )

    def test_mirror_shape_mismatch_raises(self):
        from repro.kernels.flash_expand import flash_expand_pallas

        nodes = jnp.zeros((2,), jnp.int32)
        adj = jnp.zeros((10, 4), jnp.int32)
        adt = jnp.zeros((16, 16), jnp.int32)
        bad = jnp.zeros((10, 4, 5), jnp.uint8)  # expect ceil(16/2) = 8
        with pytest.raises(ValueError, match="mirror"):
            flash_expand_pallas(nodes, adj, bad, adt)


# ---------------------------------------------------------------------------
# 2) beam parity grid: fused expand() vs gather+scan, bit-exact
# ---------------------------------------------------------------------------


def _assert_beams_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.n_dists), np.asarray(b.n_dists))
    np.testing.assert_array_equal(np.asarray(a.n_hops), np.asarray(b.n_hops))


class TestBeamParityGrid:
    def _grid_point(self, index, queries, *, width, ef, banned, warm, n_q=4):
        be = index.backend
        n = be.n
        banned_mask = (
            jnp.asarray(np.arange(n) % 7 == 0) if banned else None
        )
        visited0 = jnp.asarray(np.arange(n) % 5 == 0) if warm else None
        for qi in range(n_q):
            qctx = be.prepare_query(queries[qi])
            kw = dict(
                ef=ef, width=width, banned=banned_mask, visited0=visited0
            )
            fused = beam_search(
                be, qctx, index.adj0, jnp.asarray([0]), fused=True, **kw
            )
            fallback = beam_search(
                be, qctx, index.adj0, jnp.asarray([0]), fused=False, **kw
            )
            _assert_beams_equal(fused, fallback)

    @pytest.mark.parametrize("width", [1, 4, 8])
    @pytest.mark.parametrize("ef", [8, 48])
    def test_ref_grid(self, small_data, blocked_index, width, ef):
        _, queries = small_data
        self._grid_point(
            blocked_index, queries, width=width, ef=ef, banned=False, warm=False
        )

    @pytest.mark.parametrize("width", [1, 4, 8])
    def test_ref_grid_masked(self, small_data, blocked_index, width):
        """Tombstone mask + warm visited bitmap together."""
        _, queries = small_data
        self._grid_point(
            blocked_index, queries, width=width, ef=48, banned=True, warm=True
        )

    @pytest.mark.parametrize("width,ef", [(1, 8), (4, 8), (8, 48)])
    def test_interpret_grid(self, small_data, blocked_index, width, ef):
        """Same contract with every kernel forced through interpret-mode
        Pallas (fused expand AND the fallback's blocked scan)."""
        _, queries = small_data
        ops.set_default_impl("interpret")
        try:
            self._grid_point(
                blocked_index, queries,
                width=width, ef=ef, banned=(width == 4), warm=(width == 8),
                n_q=2,
            )
        finally:
            ops.set_default_impl(None)

    def test_vmapped_fused_matches_fallback(self, small_data, blocked_index):
        """The engine's vmapped acquire path (P queries at once)."""
        _, queries = small_data
        be = blocked_index.backend
        qctx = jax.vmap(be.prepare_query)(queries[:8])

        def run(fused):
            return jax.vmap(
                lambda qc: beam_search(
                    be, qc, blocked_index.adj0, jnp.asarray([0]),
                    ef=32, width=4, fused=fused,
                )
            )(qctx)

        _assert_beams_equal(run(True), run(False))


# ---------------------------------------------------------------------------
# 3) packed codes: round-trip, halved bytes, snapshot v2 + v1 migration
# ---------------------------------------------------------------------------


class TestPackedCodes:
    @pytest.mark.parametrize("m", [2, 7, 16])
    def test_pack_unpack_identity(self, m):
        rng = _rng(m)
        codes = jnp.asarray(rng.integers(0, 16, (40, 6, m)), jnp.int32)
        packed = flash_mod.pack_codes(codes)
        assert packed.dtype == jnp.uint8
        assert packed.shape == (40, 6, (m + 1) // 2)
        np.testing.assert_array_equal(
            np.asarray(flash_mod.unpack_codes(packed, m)), np.asarray(codes)
        )

    def test_mirror_bytes_halved(self, blocked_index):
        be = blocked_index.backend
        n, r = be.nbr_codes.shape[:2]
        m = be.coder.m_f
        assert be.nbr_codes.dtype == jnp.uint8
        # two codewords per byte: half the bytes of one-byte-per-code storage
        assert be.nbr_codes.nbytes == n * r * ((m + 1) // 2)
        assert be.nbr_codes.nbytes * 2 == n * r * m

    def test_snapshot_roundtrip_packed(self, small_data, key, tmp_path):
        from repro.index import AnnIndex
        from repro.serve import load_index, save_index

        data, queries = small_data
        idx = AnnIndex.build(
            data[:600], algo="hnsw", backend="flash_blocked",
            params=PARAMS, backend_kwargs=dict(FLASH_KW),
        )
        save_index(str(tmp_path / "snap"), idx)
        back = load_index(str(tmp_path / "snap"))
        assert back.backend.nbr_codes.dtype == jnp.uint8
        a = idx.search(queries, k=5, ef=32)
        b = back.search(queries, k=5, ef=32)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))

    def test_v1_unpacked_state_migrates(self, blocked_index, small_data):
        """A format-v1 state dict (int32 (n, R, M) mirror) restores to the
        packed layout and serves identical distances."""
        _, queries = small_data
        be = blocked_index.backend
        state = be.state_dict()
        state["nbr_codes"] = np.asarray(
            flash_mod.unpack_codes(jnp.asarray(state["nbr_codes"]), be.coder.m_f),
            dtype=np.int32,
        )
        migrated = type(be).from_state(state)
        assert migrated.nbr_codes.dtype == jnp.uint8
        np.testing.assert_array_equal(
            np.asarray(migrated.nbr_codes), np.asarray(be.nbr_codes)
        )
        qctx = be.prepare_query(queries[0])
        nodes = jnp.asarray([3, 11], jnp.int32)
        a = be.neighbor_dists_batch(qctx, nodes, blocked_index.adj0[nodes])
        b = migrated.neighbor_dists_batch(qctx, nodes, blocked_index.adj0[nodes])
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 4) capability hook (the CI guard asserts through uses_fused_expand)
# ---------------------------------------------------------------------------


class TestCapabilityHook:
    def test_only_blocked_backend_advertises(self, small_data, key):
        data, _ = small_data
        sample = data[:300]
        for kind in graph.kinds():
            kw = {}
            if kind in ("flash", "flash_blocked"):
                kw = dict(FLASH_KW)
            if kind == "flash_blocked":
                kw["r_for_blocked"] = 16
            if kind == "pq":
                kw = dict(m=8, l_pq=4, kmeans_iters=4)
            if kind == "sq":
                kw = dict(bits=8)
            if kind == "pca":
                kw = dict(alpha=0.9)
            be = graph.make_backend(kind, sample, key, **kw)
            expect = kind == "flash_blocked"
            assert uses_fused_expand(be, 16) is expect, kind
            assert uses_fused_expand(be, 8) is False, kind  # mirror mismatch

    def test_fused_true_raises_without_capability(self, small_data):
        data, queries = small_data
        be = graph.make_backend("fp32", data[:200])
        qctx = be.prepare_query(queries[0])
        adj = jnp.full((200, 8), -1, jnp.int32)
        with pytest.raises(ValueError, match="fused"):
            beam_search(be, qctx, adj, jnp.asarray([0]), ef=8, fused=True)

    def test_base_expand_not_implemented(self, small_data):
        data, queries = small_data
        be = graph.make_backend("fp32", data[:200])
        qctx = be.prepare_query(queries[0])
        with pytest.raises(NotImplementedError, match="expand"):
            be.expand(qctx, jnp.asarray([0]), jnp.full((200, 8), -1, jnp.int32))


# ---------------------------------------------------------------------------
# 5) the single-sort merge is bit-identical to the former top_k merge
# ---------------------------------------------------------------------------


class TestMergeEquivalence:
    @staticmethod
    def _merge_topk(ids_a, d_a, exp_a, ids_b, d_b, exp_b, ef):
        """The pre-refactor merge, kept verbatim as the oracle."""
        ids = jnp.concatenate([ids_a, ids_b])
        d = jnp.concatenate([d_a, d_b])
        exp = jnp.concatenate([exp_a, exp_b])
        _, idx = jax.lax.top_k(-d, ef)
        return ids[idx], d[idx], exp[idx]

    @pytest.mark.parametrize("seed", range(8))
    def test_bit_identical_with_ties(self, seed):
        rng = _rng(seed)
        ef, nb = 16, 24
        # coarse-quantized distances force plenty of exact ties (+inf pads)
        d_a = np.sort(rng.integers(0, 6, ef).astype(np.float32))
        d_a[rng.random(ef) < 0.2] = np.inf
        d_a = np.sort(d_a)
        d_b = rng.integers(0, 6, nb).astype(np.float32)
        d_b[rng.random(nb) < 0.3] = np.inf
        args = (
            jnp.asarray(rng.integers(-1, 40, ef), jnp.int32), jnp.asarray(d_a),
            jnp.asarray(rng.random(ef) < 0.5),
            jnp.asarray(rng.integers(-1, 40, nb), jnp.int32), jnp.asarray(d_b),
            jnp.asarray(rng.random(nb) < 0.5),
        )
        got = beam_mod._merge(*args, ef)
        want = self._merge_topk(*args, ef)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
