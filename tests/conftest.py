"""Shared fixtures: small clustered datasets (embedding-like) + helpers.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device; only
launch/dryrun.py forces 512 placeholder devices (in its own process).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def make_clustered(
    n: int, d: int, *, n_clusters: int = 24, sep: float = 1.0, seed: int = 0
) -> np.ndarray:
    """Gaussian-mixture data with smooth variance decay (embedding-like)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)) * sep
    # anisotropic within-cluster noise: decaying per-dim scales, like PCA
    # spectra of real embedding sets
    scales = np.linspace(1.0, 0.2, d)
    x = centers[rng.integers(0, n_clusters, n)] + rng.normal(size=(n, d)) * scales
    return x.astype(np.float32)


@pytest.fixture(scope="session")
def small_data():
    """(data (2000, 48), queries (64, 48)) jnp arrays."""
    x = make_clustered(2064, 48, seed=0)
    return jnp.asarray(x[:2000]), jnp.asarray(x[2000:])


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
