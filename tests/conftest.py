"""Shared fixtures: small clustered datasets (embedding-like) + helpers.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device; only
launch/dryrun.py forces 512 placeholder devices (in its own process).
"""

from __future__ import annotations

import random
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _install_hypothesis_stub() -> None:
    """Minimal in-process ``hypothesis`` replacement (container lacks the dep).

    Only the subset this suite uses is implemented: ``given`` + ``settings``
    decorators and the ``integers`` / ``sampled_from`` strategies. Examples are
    drawn deterministically (boundaries first, then a seeded PRNG stream), so
    runs are reproducible; ``deadline`` and shrinking are out of scope.
    """
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw  # (rng, index) -> value

    def integers(min_value=0, max_value=None, **_kw):
        lo = int(min_value)
        hi = int(max_value) if max_value is not None else 2**31 - 1

        def draw(rng, i):
            if i == 0:
                return lo
            if i == 1:
                return hi
            return rng.randint(lo, hi)

        return _Strategy(draw)

    def sampled_from(seq):
        opts = list(seq)

        def draw(rng, i):
            if i < len(opts):
                return opts[i]
            return opts[rng.randrange(len(opts))]

        return _Strategy(draw)

    def settings(max_examples=10, deadline=None, **_kw):  # noqa: ARG001
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            n_examples = getattr(fn, "_stub_max_examples", 10)

            def wrapper(*args):  # (self,) for methods, () for plain functions
                rng = random.Random(0xC0FFEE)
                for i in range(n_examples):
                    drawn = [s._draw(rng, i) for s in strategies]
                    kw = {k: s._draw(rng, i) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    hyp.strategies = st
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()


def make_clustered(
    n: int, d: int, *, n_clusters: int = 24, sep: float = 1.0, seed: int = 0
) -> np.ndarray:
    """Gaussian-mixture data with smooth variance decay (embedding-like)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)) * sep
    # anisotropic within-cluster noise: decaying per-dim scales, like PCA
    # spectra of real embedding sets
    scales = np.linspace(1.0, 0.2, d)
    x = centers[rng.integers(0, n_clusters, n)] + rng.normal(size=(n, d)) * scales
    return x.astype(np.float32)


@pytest.fixture(autouse=True, scope="module")
def _bounded_executable_cache():
    """Drop compiled executables at module boundaries.

    XLA-CPU's JIT segfaults inside ``backend_compile`` once one process
    holds a few hundred live compiled computations (reproducible at the
    same test ~70% through a full-suite run; every module passes alone).
    Clearing per module keeps the resident count bounded — modules pay
    their own compiles either way, only cross-module reuse is lost.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def small_data():
    """(data (2000, 48), queries (64, 48)) jnp arrays."""
    x = make_clustered(2064, 48, seed=0)
    return jnp.asarray(x[:2000]), jnp.asarray(x[2000:])


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
