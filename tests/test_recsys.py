"""Recsys tests: EmbeddingBag vs dense oracle (hypothesis property),
hash/QR embeddings, retrieval scorer parity, bert4rec masking semantics."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.recsys import bert4rec as b4r
from repro.models.recsys import retrieval
from repro.models.recsys.embedding import (
    embedding_bag,
    embedding_bag_oracle,
    embedding_bag_ragged,
    hash_embedding,
    qr_embedding,
)


class TestEmbeddingBag:
    @given(
        st.integers(0, 2**31 - 1),
        st.integers(1, 8),
        st.integers(1, 12),
        st.sampled_from(["sum", "mean"]),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_onehot_oracle(self, seed, b, l, reduce):
        rng = np.random.default_rng(seed)
        table = jnp.asarray(rng.normal(size=(37, 5)), jnp.float32)
        idx = jnp.asarray(rng.integers(0, 37, (b, l)), jnp.int32)
        mask = jnp.asarray(rng.uniform(size=(b, l)) > 0.3)
        got = embedding_bag(table, idx, mask, reduce=reduce)
        want = embedding_bag_oracle(table, idx, mask, reduce=reduce)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_ragged_equals_padded(self, key):
        table = jax.random.normal(key, (50, 8))
        idx = jax.random.randint(key, (4, 6), 0, 50)
        padded = embedding_bag(table, idx, None)
        ragged = embedding_bag_ragged(
            table, idx.reshape(-1), jnp.repeat(jnp.arange(4), 6), 4
        )
        # atol for near-zero sums: segment_sum and the padded reduction
        # associate float adds differently.
        np.testing.assert_allclose(np.asarray(padded), np.asarray(ragged),
                                   rtol=1e-6, atol=1e-6)

    def test_max_reduce(self, key):
        table = jax.random.normal(key, (20, 4))
        idx = jnp.asarray([[0, 1, 2]])
        mask = jnp.asarray([[True, True, False]])
        got = embedding_bag(table, idx, mask, reduce="max")
        want = jnp.max(table[:2], axis=0, keepdims=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_hash_embedding_deterministic(self, key):
        table = jax.random.normal(key, (64, 8))
        ids = jnp.asarray([12345678, 99999999])
        a = hash_embedding(table, ids)
        b = hash_embedding(table, ids)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.shape == (2, 8)

    def test_qr_embedding_covers_large_vocab(self, key):
        qt = jax.random.normal(key, (100, 8))
        rt = jax.random.normal(key, (100, 8))
        ids = jnp.asarray([0, 9999, 5432])  # vocab up to 10^4 with 200 rows
        out = qr_embedding(qt, rt, ids)
        assert out.shape == (3, 8)
        # distinct ids -> (almost surely) distinct embeddings
        assert float(jnp.max(jnp.abs(out[0] - out[1]))) > 1e-6


class TestBert4Rec:
    @pytest.fixture(scope="class")
    def setup(self, key):
        cfg = b4r.Bert4RecConfig(n_items=500, embed_dim=32, n_blocks=2,
                                 n_heads=2, seq_len=16)
        return cfg, b4r.init_bert4rec(key, cfg)

    def test_mask_position_affects_loss(self, setup, key):
        cfg, params = setup
        items, maskpos = b4r.sample_training_batch(key, cfg, 4)
        l1 = float(b4r.bert4rec_loss(params, cfg, items, maskpos))
        assert np.isfinite(l1) and l1 > 0

    def test_bidirectional_context(self, setup, key):
        """Changing a LATER item changes the encoding of an EARLIER position
        (bidirectional ≠ causal)."""
        cfg, params = setup
        items, _ = b4r.sample_training_batch(key, cfg, 1)
        h1 = b4r.bert4rec_encode(params, cfg, items)
        items2 = items.at[0, -1].set((items[0, -1] + 7) % cfg.n_items)
        h2 = b4r.bert4rec_encode(params, cfg, items2)
        assert float(jnp.max(jnp.abs(h1[0, 0] - h2[0, 0]))) > 1e-7

    def test_training_reduces_loss(self, setup, key):
        cfg, params = setup
        from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

        opt = AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=50,
                          schedule="constant")
        state = adamw_init(params)
        items, maskpos = b4r.sample_training_batch(key, cfg, 16)
        losses = []
        for _ in range(25):
            loss, grads = jax.value_and_grad(
                lambda p: b4r.bert4rec_loss(p, cfg, items, maskpos)
            )(params)
            params, state, _ = adamw_update(opt, grads, state, params)
            losses.append(float(loss))
        assert losses[-1] < losses[0]


class TestRetrieval:
    def test_flash_scan_recall(self, key):
        from repro import core

        n, d = 20000, 32
        from repro.data.synthetic import vector_dataset

        emb = jnp.asarray(vector_dataset(0, n=n, d=d, n_clusters=128))
        emb = emb / jnp.linalg.norm(emb, axis=1, keepdims=True)
        q = emb[:16] + 0.02 * jax.random.normal(key, (16, d))
        exact = retrieval.score_dense(q, emb, k=10)
        coder = core.fit_flash(key, emb[:8192], d_f=24, m_f=12, kmeans_iters=8)
        codes = core.encode(coder, emb)
        fl = retrieval.score_flash(q, coder, codes, emb, k=10, rerank=16)
        assert retrieval.retrieval_recall(fl, exact, 10) >= 0.5

    def test_dense_topk_correct(self, key):
        emb = jax.random.normal(key, (100, 8))
        emb = emb / jnp.linalg.norm(emb, axis=1, keepdims=True)  # unit rows:
        q = emb[3:4]  # self-IP = 1 is the unique maximum
        res = retrieval.score_dense(q, emb, k=1)
        assert int(res.ids[0, 0]) == 3
