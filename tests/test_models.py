"""Model-layer tests: attention/decode parity, MoE dispatch equivalence,
GNN equivariance properties, SO(3) machinery exactness."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import layers as L
from repro.models import transformer as tfm
from repro.models.gnn import so3
from repro.models.gnn.common import random_graph_batch
from repro.models.gnn.egnn import EGNNConfig, egnn_forward, init_egnn
from repro.models.gnn.equiformer_v2 import (
    EquiformerV2Config,
    equiformer_v2_forward,
    init_equiformer_v2,
)
from repro.models.gnn.nequip import NequIPConfig, init_nequip, nequip_forward
from repro.models.moe import MoEConfig, init_moe, moe_forward


def _random_rotation(seed=0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return jnp.asarray(q, jnp.float32)


class TestAttention:
    def test_blockwise_equals_full(self, key):
        """block_q-chunked causal attention == unchunked (prefill path)."""
        p = L.init_gqa(key, d_model=32, n_heads=4, n_kv=2, head_dim=8,
                       qkv_bias=False)
        x = jax.random.normal(key, (2, 64, 32))
        pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
        full = L.gqa_forward(p, x, pos, n_heads=4, n_kv=2, head_dim=8,
                             rope_theta=1e4, block_q=None)
        blocked = L.gqa_forward(p, x, pos, n_heads=4, n_kv=2, head_dim=8,
                                rope_theta=1e4, block_q=16)
        np.testing.assert_allclose(
            np.asarray(full), np.asarray(blocked), rtol=1e-4, atol=1e-4
        )

    def test_rope_preserves_norm(self, key):
        x = jax.random.normal(key, (2, 8, 4, 16))
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
        y = L.apply_rope(x, pos, 1e4)
        np.testing.assert_allclose(
            np.asarray(jnp.linalg.norm(y, axis=-1)),
            np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-4,
        )

    def test_causal_mask(self, key):
        """Changing future tokens cannot change past logits."""
        cfg = tfm.TransformerConfig(
            name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
            head_dim=16, d_ff=64, vocab=64, dtype=jnp.float32, remat=False,
        )
        params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
        t1 = jax.random.randint(key, (1, 10), 0, 64)
        t2 = t1.at[0, -1].set((t1[0, -1] + 1) % 64)
        l1, _ = tfm.lm_forward(params, cfg, t1)
        l2, _ = tfm.lm_forward(params, cfg, t2)
        np.testing.assert_allclose(
            np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), atol=1e-5
        )


class TestMoE:
    @pytest.fixture(scope="class")
    def setup(self, key):
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0)
        p = init_moe(key, d_model=16, cfg=cfg)
        x = jax.random.normal(key, (2, 16, 16))
        return cfg, p, x

    def test_scatter_equals_einsum(self, setup):
        cfg, p, x = setup
        o1, _ = moe_forward(p, x, dataclasses.replace(cfg, impl="scatter"))
        o2, _ = moe_forward(p, x, dataclasses.replace(cfg, impl="einsum"))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-5, atol=1e-5)

    def test_ep_falls_back_without_mesh(self, setup):
        cfg, p, x = setup
        o1, _ = moe_forward(p, x, dataclasses.replace(cfg, impl="scatter"))
        o3, _ = moe_forward(p, x, dataclasses.replace(cfg, impl="ep"))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o3),
                                   rtol=1e-5, atol=1e-5)

    def test_aux_losses_finite(self, setup):
        cfg, p, x = setup
        _, aux = moe_forward(p, x, cfg)
        assert np.isfinite(float(aux["load_balance"]))
        assert float(aux["load_balance"]) >= 0.99  # E·Σf·P ≥ 1 at balance

    def test_capacity_drops_reduce_output(self, setup, key):
        """Tiny capacity ⇒ tokens dropped ⇒ output differs from dropless."""
        cfg, p, x = setup
        tight = dataclasses.replace(cfg, capacity_factor=0.25)
        o_drop, _ = moe_forward(p, x, tight)
        o_full, _ = moe_forward(p, x, cfg)
        assert float(jnp.max(jnp.abs(o_drop - o_full))) > 1e-6


class TestSO3:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_rotation_closure_property(self, seed):
        """Y(Rx)·c == Y(x)·(D(R)c) for all rotations (l_max=4)."""
        rot = _random_rotation(seed)
        rng = np.random.default_rng(seed)
        c = jnp.asarray(rng.normal(size=(25,)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
        lhs = so3.real_sph_harm(4, x @ rot) @ c
        rhs = so3.real_sph_harm(4, x) @ so3.rotate_coeffs(4, c, rot)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-3, atol=1e-4)

    def test_wigner_orthogonal(self):
        rot = _random_rotation(3)
        for l, d in enumerate(so3.wigner_d_from_rot(6, rot)):
            np.testing.assert_allclose(
                np.asarray(d @ d.T), np.eye(2 * l + 1), atol=1e-4
            )

    def test_edge_rotation_maps_to_z(self, key):
        e = jax.random.normal(key, (32, 3))
        r = so3.edge_rotation(e)
        n = e / jnp.linalg.norm(e, axis=1, keepdims=True)
        z = jnp.einsum("eij,ej->ei", r, n)
        np.testing.assert_allclose(
            np.asarray(z), np.tile([0, 0, 1.0], (32, 1)), atol=1e-5
        )

    def test_gaunt_selection_rules(self):
        """G vanishes unless |l1−l2| ≤ l3 ≤ l1+l2 and l1+l2+l3 even."""
        assert np.abs(so3.gaunt_tensor(1, 1, 1)).max() < 1e-9  # odd sum
        assert np.abs(so3.gaunt_tensor(0, 1, 2)).max() < 1e-9  # triangle
        assert np.abs(so3.gaunt_tensor(1, 1, 2)).max() > 1e-3


class TestEquivariance:
    @pytest.fixture(scope="class")
    def graph(self, key):
        return random_graph_batch(
            key, n_nodes=24, n_edges=64, d_feat=6,
            with_positions=True, n_graphs=2,
        )

    def test_egnn(self, graph, key):
        cfg = EGNNConfig(n_layers=2, d_hidden=16, d_in=6)
        p = init_egnn(key, cfg)
        rot = _random_rotation(1)
        o1, x1 = egnn_forward(p, graph, cfg)
        o2, x2 = egnn_forward(p, graph._replace(positions=graph.positions @ rot.T), cfg)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-3)
        np.testing.assert_allclose(np.asarray(x1 @ rot.T), np.asarray(x2), atol=1e-2)

    def test_nequip(self, graph, key):
        cfg = NequIPConfig(n_layers=2, channels=8, l_max=2, n_rbf=4)
        p = init_nequip(key, cfg)
        rot = _random_rotation(2)
        e1, h1 = nequip_forward(p, graph, cfg)
        e2, h2 = nequip_forward(
            p, graph._replace(positions=graph.positions @ rot.T), cfg
        )
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(so3.rotate_coeffs(2, h1, rot[None])), np.asarray(h2),
            atol=1e-4,
        )

    def test_equiformer_v2(self, graph, key):
        cfg = EquiformerV2Config(
            n_layers=2, channels=16, l_max=4, m_max=2, n_heads=4, n_rbf=4
        )
        p = init_equiformer_v2(key, cfg)
        rot = _random_rotation(4)
        e1, h1 = equiformer_v2_forward(p, graph, cfg)
        e2, h2 = equiformer_v2_forward(
            p, graph._replace(positions=graph.positions @ rot.T), cfg
        )
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(so3.rotate_coeffs(4, h1, rot[None])), np.asarray(h2),
            atol=1e-3,
        )

    def test_translation_invariance(self, graph, key):
        cfg = NequIPConfig(n_layers=2, channels=8, l_max=2, n_rbf=4)
        p = init_nequip(key, cfg)
        shift = jnp.asarray([1.5, -2.0, 0.7])
        e1, _ = nequip_forward(p, graph, cfg)
        e2, _ = nequip_forward(
            p, graph._replace(positions=graph.positions + shift), cfg
        )
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-4)
