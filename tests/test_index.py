"""Tests for the unified `repro.index` facade (DESIGN.md §8).

Four contracts:
  1. The facade is a faithful front: building/searching through ``AnnIndex``
     gives exactly the direct ``build_hnsw``/``search_hnsw`` results, flat
     algorithms return the same ``SearchResult`` shape, and the registries
     (algos, backend kinds) raise informative errors.
  2. ``add()`` — the ISSUE's acceptance bar: a 25% growth batch on a
     flash_blocked HNSW index reaches recall@10 within 0.02 of a
     from-scratch build over the union at < 50% of its distance
     evaluations, keeps the blocked mirror consistent, and assigns stable
     appended ids.
  3. ``delete()`` tombstones are traversable but never returned, before and
     after ``compact()``; compaction rewires around the holes.
  4. Hygiene: no consumer of the facade imports underscore-private helpers.
"""

from __future__ import annotations

import pathlib
import re

import jax.numpy as jnp
import numpy as np
import pytest

from repro import graph
from repro.graph.hnsw import HNSWParams, build_hnsw, search_hnsw
from repro.graph.knn import exact_knn, recall_at_k
from repro.graph.segmented import SegmentedAnnIndex
from repro.index import AnnIndex, SearchResult, algos

PARAMS = HNSWParams(r_upper=8, r_base=16, ef=32, batch=16, max_layers=3)
FLASH_KW = dict(d_f=32, m_f=16, l_f=4, h=8, kmeans_iters=10)
N_BASE = 1600  # of small_data's 2000: the last 400 are the growth batch


@pytest.fixture(scope="module")
def truth(small_data):
    data, queries = small_data
    return exact_knn(queries, data, k=10)


@pytest.fixture(scope="module")
def flash_union(small_data):
    """From-scratch flash_blocked build over the full vector set."""
    data, _ = small_data
    return AnnIndex.build(
        data, algo="hnsw", backend="flash_blocked", params=PARAMS,
        backend_kwargs=FLASH_KW,
    )

@pytest.fixture(scope="module")
def flash_grown(small_data):
    """Base build on the first N_BASE vectors + add() of the rest; returns
    (index, add_stats)."""
    data, _ = small_data
    idx = AnnIndex.build(
        data[:N_BASE], algo="hnsw", backend="flash_blocked", params=PARAMS,
        backend_kwargs=FLASH_KW,
    )
    stats = idx.add(data[N_BASE:])
    return idx, stats


class TestFacade:
    def test_registry(self):
        assert set(algos()) >= {"hnsw", "vamana", "nsg"}
        with pytest.raises(ValueError, match="vamana"):
            AnnIndex.build(np.zeros((4, 2), np.float32), algo="nope")

    def test_backend_kinds_helper(self):
        assert graph.kinds() == graph.backends.KINDS
        assert "flash_blocked" in graph.kinds()

    def test_unknown_kind_error_lists_kinds(self, small_data):
        data, _ = small_data
        with pytest.raises(ValueError, match="flash_blocked"):
            graph.make_backend("nope", data)
        with pytest.raises(ValueError, match="flash_blocked"):
            AnnIndex.build(data, backend="nope")

    def test_fp32_rejects_coder_kwargs(self, small_data):
        data, _ = small_data
        with pytest.raises(ValueError, match="no coder options"):
            graph.make_backend("fp32", data, d_f=16)

    def test_facade_matches_direct_build(self, small_data, truth):
        """AnnIndex is a front, not a fork: same graph, same results.

        Pinned to strategy="incremental" — the facade's from-scratch
        default is the bulk fast path (DESIGN.md §12), which builds a
        different (equally valid) graph; bit-exactness vs the direct
        builder is an incremental-schedule contract.
        """
        data, queries = small_data
        idx = AnnIndex.build(
            data[:800], algo="hnsw", backend="fp32", params=PARAMS, seed=0,
            strategy="incremental",
        )
        be = graph.make_backend("fp32", data[:800])
        direct, _ = build_hnsw(data[:800], be, params=PARAMS, seed=0)
        np.testing.assert_array_equal(
            np.asarray(idx.graph.adj0), np.asarray(direct.adj0)
        )
        res_f = idx.search(queries, k=10, ef=64, rerank=False)
        res_d = search_hnsw(direct, queries, k=10, ef_search=64)
        np.testing.assert_array_equal(
            np.asarray(res_f.ids), np.asarray(res_d.ids)
        )

    def test_flat_algo_same_result_shape(self, small_data, truth):
        data, queries = small_data
        idx = AnnIndex.build(
            data[:800], algo="vamana", backend="fp32",
            params=HNSWParams(r_upper=8, r_base=24, ef=64, batch=16, alpha=1.2),
        )
        res = idx.search(queries, k=10, ef=96)
        assert isinstance(res, SearchResult)
        assert res.ids.shape == (queries.shape[0], 10)
        assert float(res.n_dists) > 0
        t800, _ = exact_knn(queries, data[:800], k=10)
        assert recall_at_k(res.ids, t800, 10) >= 0.85

    def test_single_query_shape(self, small_data, flash_union):
        data, queries = small_data
        res = flash_union.search(queries[0], k=5, ef=32)
        assert res.ids.shape == (5,)


class TestAdd:
    def test_acceptance_recall_and_cost(
        self, small_data, truth, flash_union, flash_grown
    ):
        """ISSUE acceptance: 25% growth via add() — recall within 0.02 of a
        full rebuild over the union, < 50% of its distance evaluations."""
        data, queries = small_data
        grown, add_stats = flash_grown
        rec_full = recall_at_k(
            flash_union.search(queries, k=10, ef=128).ids, truth[0], 10
        )
        rec_add = recall_at_k(
            grown.search(queries, k=10, ef=128).ids, truth[0], 10
        )
        assert rec_add >= rec_full - 0.02, (rec_add, rec_full)
        nd_add = float(add_stats.n_dists)
        nd_full = float(flash_union.last_stats.n_dists)
        assert nd_add < 0.5 * nd_full, (nd_add, nd_full)

    def test_added_ids_stable_and_searchable(self, small_data, flash_grown):
        """New vectors get appended ids and find themselves top-1."""
        data, queries = small_data
        grown, _ = flash_grown
        assert grown.n == data.shape[0]
        probes = jnp.asarray(data[N_BASE : N_BASE + 32])
        res = grown.search(probes, k=1, ef=64)
        hit = np.mean(
            np.asarray(res.ids)[:, 0] == np.arange(N_BASE, N_BASE + 32)
        )
        assert hit >= 0.9

    def test_blocked_mirror_consistent_after_add(self, flash_grown):
        """The §3.3.4 neighbor-code mirror must track the grown adjacency."""
        grown, _ = flash_grown
        from repro.core import unpack_codes

        adj = np.asarray(grown.graph.adj0)
        nbrc = np.asarray(
            unpack_codes(grown.backend.nbr_codes, grown.backend.coder.m_f)
        )
        codes = np.asarray(grown.backend.codes)
        for v in range(0, grown.n, 89):
            for slot, u in enumerate(adj[v]):
                if u >= 0:
                    np.testing.assert_array_equal(nbrc[v, slot], codes[u])

    def test_flat_add(self, small_data):
        data, queries = small_data
        idx = AnnIndex.build(
            data[:600], algo="vamana", backend="fp32",
            params=HNSWParams(r_upper=8, r_base=24, ef=64, batch=16, alpha=1.2),
        )
        idx.add(data[600:800])
        t800, _ = exact_knn(queries, data[:800], k=10)
        res = idx.search(queries, k=10, ef=96)
        assert recall_at_k(res.ids, t800, 10) >= 0.85

    def test_add_dim_mismatch_raises(self, flash_grown):
        grown, _ = flash_grown
        with pytest.raises(ValueError, match="dim mismatch"):
            grown.add(np.zeros((3, 7), np.float32))


class TestDelete:
    @pytest.fixture()
    def fp32_idx(self, small_data):
        data, _ = small_data
        return AnnIndex.build(
            data[:800], algo="hnsw", backend="fp32", params=PARAMS
        )

    def test_delete_compact_flow(self, small_data, fp32_idx):
        """Tombstones are never returned; compact purges and rewires."""
        data, queries = small_data
        t800, _ = exact_knn(queries, data[:800], k=10)
        victims = np.unique(np.asarray(t800[:, 0]))  # every true top-1
        assert fp32_idx.delete(victims) == len(victims)
        assert fp32_idx.delete(victims) == 0  # idempotent
        res = fp32_idx.search(queries, k=10, ef=64)
        assert not np.isin(np.asarray(res.ids), victims).any()
        # recall against the surviving ground truth stays high
        active = np.setdiff1d(np.arange(800), victims)
        t_act, _ = exact_knn(queries, data[:800][active], k=10)
        t_glob = jnp.asarray(active)[t_act]
        assert recall_at_k(res.ids, t_glob, 10) >= 0.85

        fp32_idx.compact()
        assert fp32_idx.n_active == 800 - len(victims)
        res2 = fp32_idx.search(queries, k=10, ef=64)
        assert not np.isin(np.asarray(res2.ids), victims).any()
        assert recall_at_k(res2.ids, t_glob, 10) >= 0.85
        # retired vertices are fully unlinked
        adj = np.asarray(fp32_idx.graph.adj0)
        assert not np.isin(adj, victims).any()
        assert (adj[victims] == -1).all()
        # no duplicate neighbors introduced by the rewiring
        for row in adj[::17]:
            v = row[row >= 0]
            assert len(np.unique(v)) == len(v)

    def test_delete_validation(self, fp32_idx):
        with pytest.raises(IndexError):
            fp32_idx.delete([800])
        assert fp32_idx.delete(np.array([], np.int64)) == 0


class TestSegmented:
    def test_build_search_add_delete(self, small_data, truth):
        data, queries = small_data
        S, ns = 4, 400
        segs = np.asarray(data[: S * ns]).reshape(S, ns, -1)
        seg_idx = SegmentedAnnIndex.build(
            segs, algo="hnsw", backend="fp32", params=PARAMS
        )
        t_all, _ = exact_knn(queries, data[: S * ns], k=10)
        res = seg_idx.search(queries, k=10, ef=64)
        assert recall_at_k(res.ids, t_all, 10) >= 0.9

        extra = np.asarray(data[S * ns : S * ns + 32])
        gids = seg_idx.add(extra)
        assert seg_idx.n == S * ns + 32
        self_hit = np.mean(
            np.asarray(seg_idx.search(extra, k=1, ef=64).ids)[:, 0] == gids
        )
        assert self_hit >= 0.9

        assert seg_idx.delete(gids[:8]) == 8
        res2 = seg_idx.search(extra[:8], k=5, ef=64)
        assert not np.isin(np.asarray(res2.ids), gids[:8]).any()
        seg_idx.compact()
        res3 = seg_idx.search(extra[:8], k=5, ef=64)
        assert not np.isin(np.asarray(res3.ids), gids[:8]).any()


class TestFacadeHygiene:
    def test_no_private_imports_around_the_facade(self):
        """The facade composes public API only — and its consumers use its
        public API only (no `from repro.graph.index import _x` anywhere,
        no `from repro.graph.<mod> import _x` inside index.py)."""
        root = pathlib.Path(__file__).resolve().parents[1]
        private_from_index = re.compile(
            r"from\s+repro(\.graph)?\.index\s+import\s+[^#\n]*(?<![\w])_[a-z]"
        )
        offenders = []
        for base in ("src", "benchmarks", "examples"):
            for py in (root / base).rglob("*.py"):
                for line in py.read_text().splitlines():
                    if private_from_index.search(line):
                        offenders.append(f"{py}: {line.strip()}")
        facade = (root / "src/repro/graph/index.py").read_text()
        private_into_facade = re.compile(
            r"from\s+repro\.graph\.\w+\s+import\s+[^#\n]*(?<![\w])_[a-z]"
        )
        for line in facade.splitlines():
            if private_into_facade.search(line):
                offenders.append(f"index.py: {line.strip()}")
        assert not offenders, "\n".join(offenders)

    def test_benchmarks_and_examples_use_the_facade(self):
        """Consumers outside src/ and tests/ go through ``repro.index`` (or
        the segmented/serve layers), never the per-algorithm builders —
        so a facade-level feature (tombstones, rerank, telemetry) is never
        silently bypassed by a benchmark or example."""
        root = pathlib.Path(__file__).resolve().parents[1]
        direct = re.compile(
            r"import\s+[^#\n]*\b(build_hnsw|build_vamana|build_nsg|"
            r"search_hnsw|search_flat|search_flat_result)\b"
        )
        offenders = []
        for base in ("benchmarks", "examples"):
            for py in (root / base).rglob("*.py"):
                for i, line in enumerate(py.read_text().splitlines(), 1):
                    if direct.search(line):
                        offenders.append(f"{py}:{i}: {line.strip()}")
        assert not offenders, "\n".join(offenders)
