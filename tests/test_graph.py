"""Integration + property tests for the graph-index substrate.

Covers: beam search invariants, neighbor-selection (MRNG rule), HNSW build +
search recall per backend, reverse-edge integrity, Vamana/NSG generality,
segmented build/search parity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import graph
from repro.graph import segmented as seg
from repro.graph.beam import beam_search
from repro.graph.hnsw import (
    HNSWParams,
    build_hnsw,
    prefix_entries,
    sample_levels,
    search_hnsw,
)
from repro.graph.knn import average_distance_ratio, exact_knn, recall_at_k
from repro.graph.nsg import build_nsg
from repro.graph.select import select_neighbors
from repro.graph.vamana import build_vamana, search_flat_result

PARAMS = HNSWParams(r_upper=8, r_base=16, ef=32, batch=16, max_layers=3)


@pytest.fixture(scope="module")
def truth(small_data):
    data, queries = small_data
    ids, d = exact_knn(queries, data, k=10)
    return ids, d


@pytest.fixture(scope="module")
def fp32_index(small_data):
    data, _ = small_data
    be = graph.make_backend("fp32", data)
    index, stats = build_hnsw(data, be, params=PARAMS)
    return index, stats


@pytest.fixture(scope="module")
def flash_index(small_data, key):
    data, _ = small_data
    be = graph.make_backend(
        "flash", data, key, d_f=32, m_f=16, l_f=4, h=8, kmeans_iters=10
    )
    index, stats = build_hnsw(data, be, params=PARAMS)
    return index, stats


class TestLevels:
    def test_levels_distribution(self):
        lv = sample_levels(0, 100000, r_upper=16, max_layers=6)
        assert lv.min() == 0 and lv.max() <= 5
        # exponential decay: each layer ~1/R_upper of the previous
        frac1 = (lv >= 1).mean()
        assert 0.02 < frac1 < 0.12  # 1/16 ≈ 0.0625

    def test_prefix_entries(self):
        lv = np.array([0, 2, 0, 1, 3, 0, 0, 0], np.int32)
        ent = prefix_entries(lv, 2)
        np.testing.assert_array_equal(ent, [-1, 1, 1, 4])


class TestBeam:
    def test_beam_sorted_and_visits_once(self, small_data):
        data, _ = small_data
        be = graph.make_backend("fp32", data)
        # ring adjacency: node i -> i±1 … a path graph
        n = data.shape[0]
        adj = jnp.stack(
            [jnp.arange(1, n + 1) % n, jnp.arange(-1, n - 1) % n], axis=1
        ).astype(jnp.int32)
        qctx = be.prepare_query(data[5])
        res = beam_search(be, qctx, adj, jnp.asarray([0]), ef=8)
        d = np.asarray(res.dists)
        assert np.all(np.diff(d[np.isfinite(d)]) >= 0)  # ascending
        ids = np.asarray(res.ids)
        valid = ids[ids >= 0]
        assert len(np.unique(valid)) == len(valid)  # no duplicates

    def test_beam_finds_true_nn_on_full_graph(self, small_data):
        """On a graph where the entry connects to everything, beam == brute."""
        data, _ = small_data
        n = data.shape[0]
        be = graph.make_backend("fp32", data[:257])
        adj = jnp.full((257, 256), -1, jnp.int32)
        adj = adj.at[0].set(jnp.arange(1, 257))
        q = data[300]
        res = beam_search(be, be.prepare_query(q), adj, jnp.asarray([0]), ef=8)
        true = np.argsort(np.asarray(jnp.sum((data[:257] - q) ** 2, -1)))[:1]
        assert int(res.ids[0]) == int(true[0])


class TestSelect:
    def test_respects_r(self, small_data):
        data, _ = small_data
        be = graph.make_backend("fp32", data)
        q = data[0]
        d = be.query_dists(be.prepare_query(q), jnp.arange(64))
        order = jnp.argsort(d)
        sel = select_neighbors(be, order.astype(jnp.int32), d[order], r=8)
        assert int(sel.count) <= 8
        assert int(jnp.sum(sel.ids >= 0)) == int(sel.count)

    def test_mrng_rule_holds(self, small_data):
        """For every selected pair (u later than v): δ(u,v) ≥ δ(u,x)."""
        data, _ = small_data
        be = graph.make_backend("fp32", data)
        q = data[0]
        ids = jnp.arange(1, 129, dtype=jnp.int32)
        d = be.query_dists(be.prepare_query(q), ids)
        order = jnp.argsort(d)
        sel = select_neighbors(be, ids[order], d[order], r=16)
        sids = np.asarray(sel.ids)
        sd = np.asarray(sel.dists)
        chosen = sids[sids >= 0]
        cd = sd[sids >= 0]
        for i in range(len(chosen)):
            for j in range(i):
                pd = float(
                    be.pair_dists(jnp.asarray(chosen[i]), jnp.asarray(chosen[j]))
                )
                assert pd >= cd[i] - 1e-5  # no selected u dominates v

    def test_selected_sorted_ascending(self, small_data):
        data, _ = small_data
        be = graph.make_backend("fp32", data)
        d = be.query_dists(be.prepare_query(data[0]), jnp.arange(64))
        order = jnp.argsort(d)
        sel = select_neighbors(be, order.astype(jnp.int32), d[order], r=8)
        sd = np.asarray(sel.dists)
        assert np.all(np.diff(sd[np.isfinite(sd)]) >= 0)


class TestHNSWBuild:
    def test_fp32_recall(self, small_data, fp32_index, truth):
        data, queries = small_data
        index, _ = fp32_index
        res = search_hnsw(index, queries, k=10, ef_search=64, max_layers=3)
        assert recall_at_k(res.ids, truth[0], 10) >= 0.9

    def test_flash_recall_with_rerank(self, small_data, flash_index, truth):
        data, queries = small_data
        index, _ = flash_index
        res = search_hnsw(
            index, queries, k=10, ef_search=128, max_layers=3, rerank_vectors=data
        )
        assert recall_at_k(res.ids, truth[0], 10) >= 0.85

    def test_flash_build_quality_matches_fp32_graph(
        self, small_data, flash_index, fp32_index, truth
    ):
        """Graph built with Flash codes, searched in fp32: recall stays high —
        the paper's core claim (compressed comparisons build a good graph)."""
        data, queries = small_data
        index, _ = flash_index
        fp_be = graph.make_backend("fp32", data)
        mixed = index._replace(backend=fp_be)
        res = search_hnsw(mixed, queries, k=10, ef_search=64, max_layers=3)
        assert recall_at_k(res.ids, truth[0], 10) >= 0.85

    def test_adjacency_wellformed(self, fp32_index, small_data):
        data, _ = small_data
        index, _ = fp32_index
        adj = np.asarray(index.adj0)
        n = data.shape[0]
        assert adj.shape == (n, PARAMS.r_base)
        assert adj.min() >= -1 and adj.max() < n
        # no self loops
        self_loop = adj == np.arange(n)[:, None]
        assert not self_loop.any()
        # mean degree is healthy (connected-ish graph)
        deg = (adj >= 0).sum(1)
        assert deg.mean() > 4

    def test_no_duplicate_neighbors(self, fp32_index):
        index, _ = fp32_index
        adj = np.asarray(index.adj0)
        for row in adj[:200]:
            v = row[row >= 0]
            assert len(np.unique(v)) == len(v)

    def test_upper_layers_sparse(self, fp32_index, small_data):
        data, _ = small_data
        index, _ = fp32_index
        lv = np.asarray(index.levels)
        up = np.asarray(index.adj_up[0])
        # only vertices with level >= 1 may have layer-1 edges
        has_edges = (up >= 0).any(1)
        assert not has_edges[lv < 1].any()

    def test_build_stats_positive(self, fp32_index):
        _, stats = fp32_index
        assert float(stats.n_dists) > 0 and float(stats.n_hops) > 0

    def test_adr_close_to_one(self, small_data, flash_index, truth):
        data, queries = small_data
        index, _ = flash_index
        res = search_hnsw(
            index, queries, k=10, ef_search=128, max_layers=3, rerank_vectors=data
        )
        adr = average_distance_ratio(res.dists, truth[1], 10)
        assert adr < 1.15


class TestBackendsBuild:
    @pytest.mark.parametrize(
        "kind,kw,min_recall",
        [
            ("sq", dict(bits=8), 0.85),
            ("pca", dict(alpha=0.9), 0.6),
            ("pq", dict(m=8, l_pq=6, kmeans_iters=6), 0.5),
        ],
    )
    def test_backend_recall(self, small_data, key, truth, kind, kw, min_recall):
        data, queries = small_data
        be = graph.make_backend(kind, data, key, **kw)
        index, _ = build_hnsw(data, be, params=PARAMS)
        res = search_hnsw(
            index, queries, k=10, ef_search=96, max_layers=3, rerank_vectors=data
        )
        assert recall_at_k(res.ids, truth[0], 10) >= min_recall

    def test_flash_blocked_equals_flash(self, small_data, key, truth):
        """The access-aware layout changes memory traffic, not results."""
        data, queries = small_data
        be_b = graph.make_backend(
            "flash_blocked", data, key, d_f=32, m_f=16, l_f=4, h=8,
            kmeans_iters=10, r_for_blocked=PARAMS.r_base,
        )
        index_b, _ = build_hnsw(data, be_b, params=PARAMS)
        be_f = graph.FlashBackend(be_b.coder, be_b.codes)
        index_f, _ = build_hnsw(data, be_f, params=PARAMS)
        np.testing.assert_array_equal(
            np.asarray(index_b.adj0), np.asarray(index_f.adj0)
        )
        # and the (4-bit packed) mirror is consistent with the adjacency
        from repro.core import unpack_codes

        adj = np.asarray(index_b.adj0)
        m_f = index_b.backend.coder.m_f
        nbrc = np.asarray(unpack_codes(index_b.backend.nbr_codes, m_f))
        codes = np.asarray(index_b.backend.codes)
        for v in range(0, 200, 17):
            for slot, u in enumerate(adj[v]):
                if u >= 0:
                    np.testing.assert_array_equal(nbrc[v, slot], codes[u])


class TestGenerality:
    def test_vamana_fp32(self, small_data, truth):
        data, queries = small_data
        be = graph.make_backend("fp32", data)
        idx, _ = build_vamana(data, be, params=HNSWParams(
            r_upper=8, r_base=24, ef=96, batch=16, alpha=1.2))
        res = search_flat_result(idx, queries, k=10, ef_search=96)
        assert recall_at_k(res.ids, truth[0], 10) >= 0.9

    def test_vamana_flash(self, small_data, key, truth):
        data, queries = small_data
        be = graph.make_backend("flash", data, key, d_f=32, m_f=16, kmeans_iters=10)
        idx, _ = build_vamana(data, be, params=HNSWParams(
            r_upper=8, r_base=24, ef=96, batch=16, alpha=1.2))
        res = search_flat_result(idx, queries, k=10, ef_search=128, rerank_vectors=data)
        assert recall_at_k(res.ids, truth[0], 10) >= 0.9

    def test_nsg_flash(self, small_data, key, truth):
        data, queries = small_data
        be = graph.make_backend("flash", data, key, d_f=32, m_f=16, kmeans_iters=10)
        (idx, _knn) = build_nsg(
            data, be, params=HNSWParams(r_base=24, ef=96, batch=16), knn_k=24
        )
        res = search_flat_result(idx, queries, k=10, ef_search=128, rerank_vectors=data)
        assert recall_at_k(res.ids, truth[0], 10) >= 0.8


class TestSegmented:
    def test_build_and_merge(self, small_data, key, truth):
        data, queries = small_data
        S, ns = 4, 500
        segs = data[: S * ns].reshape(S, ns, -1)
        coder = seg.fit_shared_coder(key, data, d_f=32, m_f=16, kmeans_iters=10)
        levels = np.stack(
            [sample_levels(s, ns, r_upper=8, max_layers=3) for s in range(S)]
        )
        entries = np.stack([prefix_entries(levels[s], 16) for s in range(S)])
        built = seg.build_segments_vmapped(
            segs, coder, jnp.asarray(levels), jnp.asarray(entries), params=PARAMS
        )
        gids, gd = seg.search_segments_local(
            built, queries, np.full(S, ns), k=10, ef_search=64, max_layers=3,
            seg_vectors=segs,
        )
        assert recall_at_k(gids, truth[0], 10) >= 0.9

    def test_shard_map_matches_vmap(self, small_data, key):
        """shard_map deployment ≡ vmap reference on a 1-device mesh."""
        data, _ = small_data
        S, ns = 2, 500
        segs = data[: S * ns].reshape(S, ns, -1)
        coder = seg.fit_shared_coder(key, data, d_f=16, m_f=8, kmeans_iters=6)
        levels = np.stack(
            [sample_levels(s, ns, r_upper=8, max_layers=3) for s in range(S)]
        )
        entries = np.stack([prefix_entries(levels[s], 16) for s in range(S)])
        ref = seg.build_segments_vmapped(
            segs, coder, jnp.asarray(levels), jnp.asarray(entries), params=PARAMS
        )
        mesh = jax.make_mesh((1,), ("data",))
        f = seg.make_segmented_build_fn(mesh, params=PARAMS, seg_axes=("data",))
        got = f(segs, coder, jnp.asarray(levels), jnp.asarray(entries))
        np.testing.assert_array_equal(
            np.asarray(got.adj0), np.asarray(ref.index.adj0)
        )
