"""Substrate tests: optimizer, checkpointing (fault tolerance), compression,
elastic restart, data pipeline determinism, neighbor sampler."""

from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.pipeline import microbatch_reshape, prefetch, sharded_batches
from repro.data.sampler import sample_subgraph
from repro.data.synthetic import lm_batch, random_csr_graph
from repro.train import checkpoint as ck
from repro.train import compression as comp
from repro.train.elastic import reassign_data_shards, validate_divisibility
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, schedule_lr
from repro.utils import fingerprint


class TestOptimizer:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, schedule="constant")
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(150):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw_update(cfg, grads, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.1

    def test_clip_norm(self):
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros((4,))}
        state = adamw_init(params)
        _, _, m = adamw_update(cfg, {"w": jnp.full((4,), 100.0)}, state, params)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_shapes(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in (0, 5, 10, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[3] == pytest.approx(0.1, rel=1e-2)

    def test_bf16_state_dtype(self):
        params = {"w": jnp.zeros((4,), jnp.bfloat16)}
        state = adamw_init(params, state_dtype="bf16")
        assert state.mu["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_roundtrip_and_verify(self):
        tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
                "b": {"c": np.asarray([1, 2, 3], np.int32)}}
        with tempfile.TemporaryDirectory() as td:
            ck.save_checkpoint(td, 7, tree)
            restored, step = ck.restore_checkpoint(td, tree)
            assert step == 7
            assert fingerprint(restored) == fingerprint(tree)

    def test_keep_k_pruning(self):
        tree = {"a": np.zeros(3)}
        with tempfile.TemporaryDirectory() as td:
            for s in range(6):
                ck.save_checkpoint(td, s, tree, keep=3)
            assert ck.list_checkpoints(td) == [3, 4, 5]

    def test_corruption_detected(self):
        tree = {"a": np.arange(100, dtype=np.float32)}
        with tempfile.TemporaryDirectory() as td:
            path = ck.save_checkpoint(td, 1, tree)
            # corrupt the array file
            npz = os.path.join(path, "arrays.npz")
            data = dict(np.load(npz))
            data["a0"][3] += 1.0
            np.savez(npz, **data)
            with pytest.raises(IOError):
                ck.restore_checkpoint(td, tree)

    def test_shape_mismatch_detected(self):
        tree = {"a": np.zeros((3, 4))}
        with tempfile.TemporaryDirectory() as td:
            ck.save_checkpoint(td, 1, tree)
            with pytest.raises(ValueError):
                ck.restore_checkpoint(td, {"a": np.zeros((4, 3))})

    def test_atomicity_no_tmp_left(self):
        tree = {"a": np.zeros(3)}
        with tempfile.TemporaryDirectory() as td:
            ck.save_checkpoint(td, 1, tree)
            assert not any(n.endswith(".tmp") for n in os.listdir(td))


class TestCompression:
    def test_bf16_roundtrip_small_error(self, key):
        g = {"w": jax.random.normal(key, (128,))}
        out = comp.decompress_f32(comp.compress_bf16(g))
        err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
        assert err < 0.02

    def test_int8_error_feedback_unbiased(self, key):
        """With EF, the accumulated quantization error stays bounded and the
        *sum* of dequantized gradients tracks the sum of true gradients."""
        g_true = jax.random.normal(key, (256,)) * 0.01
        ef = comp.ef_init({"w": g_true})
        total_q = jnp.zeros_like(g_true)
        for i in range(20):
            qs, scales, ef = comp.compress_int8({"w": g_true}, ef)
            total_q += comp.decompress_int8(qs, scales)["w"]
        drift = float(jnp.max(jnp.abs(total_q - 20 * g_true)))
        scale = float(jnp.max(jnp.abs(g_true)))
        assert drift < scale  # bounded by one quantization step overall


class TestElastic:
    def test_reassign_deterministic(self):
        a = reassign_data_shards(16, [0, 1, 3])
        b = reassign_data_shards(16, [3, 1, 0])
        assert a == b
        assert sorted(sum(a.values(), [])) == list(range(16))

    def test_divisibility_guard(self):
        mesh = jax.make_mesh((1,), ("model",))
        from jax.sharding import PartitionSpec as P

        assert validate_divisibility((16, 4), P("model", None), mesh)

    def test_restart_replays_same_data(self):
        mk = lambda step, shard: lm_batch(0, step, shard, batch=2, seq=8, vocab=50)
        it1 = sharded_batches(mk, shard_id=0)
        batches = [next(it1) for _ in range(5)]
        it2 = sharded_batches(mk, shard_id=0, start_step=3)
        resumed = next(it2)
        np.testing.assert_array_equal(
            np.asarray(batches[3]["tokens"]), np.asarray(resumed["tokens"])
        )


class TestDataPipeline:
    def test_prefetch_preserves_order(self):
        it = prefetch(iter(range(10)), size=3)
        assert list(it) == list(range(10))

    def test_microbatch_reshape(self):
        b = {"x": jnp.zeros((8, 4))}
        out = microbatch_reshape(b, 4)
        assert out["x"].shape == (4, 2, 4)

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_batches_deterministic(self, step):
        a = lm_batch(0, step, 1, batch=2, seq=8, vocab=100)
        b = lm_batch(0, step, 1, batch=2, seq=8, vocab=100)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


class TestSampler:
    def test_fanout_bounds(self):
        indptr, indices = random_csr_graph(0, n_nodes=300, avg_degree=6)
        rng = np.random.default_rng(0)
        sub = sample_subgraph(indptr, indices, np.arange(8),
                              fanouts=[4, 3], rng=rng)
        b = 8
        assert sub["node_ids"].shape == (b + b * 4 + b * 12,)
        assert sub["senders"].shape == (b * 4 + b * 12,)
        # all real edges point to already-sampled parents
        ne = int(sub["edge_mask"].sum())
        assert (sub["receivers"][:ne] < len(sub["node_ids"])).all()

    def test_edges_reference_valid_nodes(self):
        indptr, indices = random_csr_graph(1, n_nodes=100, avg_degree=4)
        rng = np.random.default_rng(1)
        sub = sample_subgraph(indptr, indices, np.arange(4),
                              fanouts=[3, 2], rng=rng)
        ne = int(sub["edge_mask"].sum())
        valid = sub["node_ids"] >= 0
        assert valid[sub["senders"][:ne]].all()
        assert valid[sub["receivers"][:ne]].all()
